"""Pump offload + per-graph telemetry labels: the event loop stays live
while a wave computes on the worker thread, offload=False restores in-loop
execution, a mid-wave delta cannot poison the cache (epoch-pinned fills),
resolved futures imply completed wave accounting, and the queries/shed/
degraded counters carry per-graph labels."""
import asyncio
import threading

import numpy as np
import pytest

from repro.graphs import holme_kim_powerlaw
from repro.graph_updates import localized_delta
from repro.ppr_serving import (
    AdmissionConfig,
    AdmissionController,
    PPRHTTPServer,
    PPRQuery,
    PPRService,
)
from repro.ppr_serving.http import WavePump, http_request


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(300, m=3, seed=7)


# ---------------------------------------------------------------------------
# the offload itself
# ---------------------------------------------------------------------------
def test_loop_answers_healthz_while_wave_computes(graph):
    """The ROADMAP item-3 seam, closed: with the default offload, a wave
    stuck on the worker thread must not stop the loop from serving
    /v1/healthz — the old in-loop pump would have blocked here."""
    svc = PPRService(kappa=1, iterations=3, max_wait=100.0)
    svc.register_graph("g", graph)
    svc.run_batch([PPRQuery("g", 0, k=3)])      # jit warm, off the clock
    started, release = threading.Event(), threading.Event()
    orig = svc._run_wave

    def stuck_wave(wave):
        started.set()
        assert release.wait(30.0), "test released nothing"
        return orig(wave)

    svc._run_wave = stuck_wave
    server = PPRHTTPServer(svc, pump_interval_s=0.002)

    async def scenario():
        await server.start()
        host, port = server.host, server.port
        post = asyncio.create_task(http_request(
            host, port, "POST", "/v1/ppr",
            {"graph": "g", "vertex": 7, "k": 4}))
        loop = asyncio.get_running_loop()
        deadline = loop.time() + 10.0
        while not started.is_set():
            assert loop.time() < deadline, "wave never launched"
            await asyncio.sleep(0.002)
        # the wave is parked on the worker thread right now; the loop must
        # still answer — this await would deadlock on the in-loop pump
        status, _, health = await http_request(host, port,
                                               "GET", "/v1/healthz")
        assert status == 200 and not post.done()
        release.set()
        status, _, payload = await post
        assert status == 200
        assert [r["vertex"] for r in payload["recommendations"]]
        await server.stop()

    asyncio.run(scenario())
    assert server.pump._executor is None        # stop() tore the worker down


def test_resolved_future_implies_completed_wave_accounting(graph):
    """The race /v1/metrics exposed: a handler wakes the moment its future
    resolves, so resolution must be the *last* thing a wave does — counters
    and traces land first.  Checked at the seam: when the HTTP response
    arrives, ppr_waves_total is already incremented."""
    svc = PPRService(kappa=1, iterations=3, max_wait=100.0)
    svc.register_graph("g", graph)
    svc.run_batch([PPRQuery("g", 0, k=3)])
    svc.telemetry.reset()
    server = PPRHTTPServer(svc, pump_interval_s=0.002)

    async def scenario():
        await server.start()
        host, port = server.host, server.port
        for i, v in enumerate((3, 9, 11), start=1):
            status, _, _ = await http_request(
                host, port, "POST", "/v1/ppr",
                {"graph": "g", "vertex": v, "k": 4})
            assert status == 200
            # no sleep, no drain: the counter must already be visible
            assert svc.telemetry.waves == i
            assert svc.telemetry.queries_served == i
        await server.stop()

    asyncio.run(scenario())


def test_offload_false_runs_waves_in_loop(graph):
    """offload=False is the single-threaded debug mode: no executor exists,
    and waves still resolve (in the loop thread, as before the offload)."""
    svc = PPRService(kappa=1, iterations=3, max_wait=100.0)
    svc.register_graph("g", graph)
    pump = WavePump(svc, interval_s=0.001, offload=False)

    async def scenario():
        pump.start()
        assert pump._executor is None
        fut = svc.submit(PPRQuery("g", 5, k=4))
        deadline = asyncio.get_running_loop().time() + 10.0
        while not fut.done():
            assert asyncio.get_running_loop().time() < deadline
            await asyncio.sleep(0.002)
        await pump.stop()
        return fut.result()

    rec = asyncio.run(scenario())
    assert rec.source == "wave" and len(rec.vertices) == 4
    assert pump._executor is None


def test_mid_wave_delta_cannot_poison_cache(graph):
    """With the offload, apply_delta can land while a wave computes.  The
    wave's cache fills are pinned to the epoch it was *launched* under, so
    its stale results can never masquerade as post-delta entries."""
    svc = PPRService(kappa=1, iterations=4, max_wait=100.0)
    svc.register_graph("g", graph)
    d = localized_delta(graph, np.random.default_rng(11), n_add=3, n_remove=1)
    frontier = set(int(v) for v in d.affected_frontier(graph))
    vertex = next(v for v in range(graph.num_vertices) if v not in frontier)

    q = PPRQuery("g", vertex, k=5)
    fut = svc.submit(q)
    # reproduce the race deterministically: pop the wave (what poll() does on
    # the worker thread)...
    with svc._lock:
        popped = svc.scheduler.flush_keys([fut._wave_key])
    assert len(popped) == 1
    old_epoch = svc._graphs["g"].epoch
    # ...let the delta land "mid-wave"...
    svc.apply_delta("g", d)
    assert svc._graphs["g"].epoch == old_epoch + 1
    # ...then finish the wave.  Its result resolves the future (computed on
    # the topology the caller was admitted under)...
    svc._run_wave(popped[0])
    assert fut.done() and fut.result().source == "wave"
    # ...and its cache fill sits under the OLD epoch, unreachable from the
    # new one: resubmitting must miss and queue a fresh computation
    pkey = fut.result().precision
    assert svc._cache_key(q, pkey, epoch=old_epoch) in svc.cache
    fut2 = svc.submit(q)
    assert not fut2.done()                      # miss -> queued, not stale hit
    svc.flush()
    assert fut2.result().source == "wave"


# ---------------------------------------------------------------------------
# per-graph counter labels
# ---------------------------------------------------------------------------
def test_queries_served_labeled_by_graph(graph):
    svc = PPRService(kappa=2, iterations=3, max_wait=100.0)
    svc.register_graph("a", graph)
    svc.register_graph("b", graph)
    svc.run_batch([PPRQuery("a", v, k=3) for v in range(3)] +
                  [PPRQuery("b", v, k=3) for v in range(2)])
    t = svc.telemetry
    assert t.queries_served_by_graph == {"a": 3, "b": 2}
    assert t.queries_served == 5                # legacy scalar = sum of series


def test_shed_and_degraded_counters_labeled_by_graph(graph):
    svc = PPRService(kappa=64, iterations=3, max_wait=100.0)
    svc.register_graph("g", graph, formats=[26])
    # park 4 queries in a partial wave (kappa=64 never fills) so the
    # controller sees a real depth above high_water
    futs = [svc.submit(PPRQuery("g", v, k=3)) for v in range(4)]
    ctrl = AdmissionController(svc, AdmissionConfig(
        high_water=2, low_water=1, deepen_water=500, kappa_max=64))
    assert ctrl.admit(graph="g") is not None    # shed, attributed
    assert ctrl.admit() is not None             # shed, unattributed
    t = svc.telemetry
    assert t.queries_shed_by_graph == {"g": 1, t.UNATTRIBUTED: 1}
    assert t.queries_shed == 2

    # SLO degradation counts against the graph whose query was degraded
    svc.degrade_quality(0.90)
    svc.submit(PPRQuery("g", 9, k=3, precision="auto", quality_target=0.95))
    assert t.slo_degraded_queries_by_graph == {"g": 1}
    assert t.slo_degraded_queries == 1
    svc.flush()
    assert all(f.done() for f in futs)
