"""Fused attention kernel vs oracle: shape/window/causal sweeps + GQA wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_gqa, flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("sq,skv,d,bq,bk,causal,window", [
    (128, 128, 64, 64, 64, True, 0),
    (256, 256, 32, 128, 128, True, 0),
    (128, 256, 64, 64, 64, False, 0),     # cross-attention-like
    (256, 256, 64, 64, 64, True, 64),     # local window
    (128, 128, 128, 128, 128, True, 32),  # window < block
])
def test_flash_matches_ref(sq, skv, d, bq, bk, causal, window):
    rng = np.random.default_rng(sq + skv + d)
    bh = 3
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 bq=bq, bk=bk, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_gqa_wrapper_matches_attention_module():
    """The GQA wrapper agrees with the model stack's reference attention."""
    import dataclasses
    from repro.configs import get_config, smoke_config
    from repro.models.attention import _attend

    cfg = dataclasses.replace(smoke_config(get_config("gemma2-27b")),
                              attn_softcap=0.0, compute_dtype="float32")
    rng = np.random.default_rng(0)
    b, s, h, kv, hd = 2, 128, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
    out = flash_attention_gqa(q, k, v, causal=True, bq=64, bk=64)
    ref = _attend(q, k, v, jnp.arange(s), jnp.arange(s), cfg, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_block_shape_check():
    q = jnp.zeros((1, 100, 64), jnp.float32)
    with pytest.raises(ValueError):
        flash_attention_pallas(q, q, q, bq=64, bk=64)
