"""Windowed rolling-buffer KV cache (§Perf it_windowed_kv made real):
decode with O(window) caches must produce the same logits as full caches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["gemma2-27b", "mixtral-8x7b", "gemma3-4b"])
def test_windowed_decode_matches_full(arch):
    cfg = dataclasses.replace(smoke_config(get_config(arch)),
                              compute_dtype="float32")
    # ensure small windows so the rolling buffer actually wraps
    cfg = dataclasses.replace(
        cfg, layer_pattern=tuple(4 if w > 0 else w for w in cfg.layer_pattern))
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, sp, n_new, max_len = 2, 10, 8, 32
    toks = rng.integers(0, cfg.vocab_size, (b, sp + n_new)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks[:, :sp])}

    def run(window_cache):
        cache = api.init_cache(b, max_len, window_cache=window_cache)
        logits, cache = api.prefill(params, batch, cache)
        outs = [np.asarray(logits)]
        for t in range(n_new):
            logits, cache = api.decode_step(
                params, jnp.asarray(toks[:, sp + t: sp + t + 1]),
                jnp.asarray(sp + t, jnp.int32), cache)
            outs.append(np.asarray(logits))
        return outs

    full = run(False)
    win = run(True)
    for t, (a, b_) in enumerate(zip(full, win)):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-5,
                                   err_msg=f"step {t}")


def test_windowed_cache_is_smaller():
    cfg = dataclasses.replace(smoke_config(get_config("gemma2-27b")),
                              compute_dtype="float32")
    api = build_model(cfg, remat=False)
    full = api.init_cache(2, 64, window_cache=False)
    win = api.init_cache(2, 64, window_cache=True)
    bytes_full = sum(x.size for x in jax.tree.leaves(full))
    bytes_win = sum(x.size for x in jax.tree.leaves(win))
    assert bytes_win < bytes_full  # local layers capped at their window
