"""End-to-end behaviour tests for the paper's system: the full PPR pipeline
(graph → quantize → batched fixed-point PPR → ranking quality) and the
quantization integration points shared with the LM framework."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PPRConfig, Q1_25, batched_ppr, format_for_bits, run_ppr
from repro.core.metrics import aggregate_reports, full_report
from repro.core.quantization import (
    ErrorFeedbackQuantizer,
    dequantize,
    quantize_weights,
    truncate_to_grid,
)
from repro.graphs import paper_graph_suite, ppr_reference


def test_paper_pipeline_end_to_end():
    """The paper's §5 protocol at CI scale: 16 requests, κ=8, 10 iterations,
    26-bit fixed point → ranking matches the converged CPU oracle."""
    g = paper_graph_suite(scale=0.01)["pl_1e5"]
    rng = np.random.default_rng(0)
    vertices = rng.integers(0, g.num_vertices, 16)
    scores = batched_ppr(g, vertices, PPRConfig(iterations=10, kappa=8), fmt=Q1_25)
    ref = ppr_reference(g, vertices, iterations=100)
    reports = [full_report(scores[:, i], ref[:, i]) for i in range(len(vertices))]
    agg = aggregate_reports(reports)
    assert agg["ndcg"] > 0.999
    assert agg["edit@10"] <= 1.5
    assert agg["precision@50"] > 0.95


def test_all_paper_graph_distributions_build():
    suite = paper_graph_suite(scale=0.005)
    assert set(suite) == {"gnp_1e5", "gnp_2e5", "ws_1e5", "ws_2e5",
                          "pl_1e5", "pl_2e5", "amazon_like", "twitter_like"}
    for name, g in suite.items():
        assert g.num_edges > 0
        assert (g.val > 0).all()
        # column-stochastic X: out-mass of every non-dangling vertex ≈ 1
        mass = np.bincount(g.y, weights=g.val, minlength=g.num_vertices)
        nd = ~g.dangling
        np.testing.assert_allclose(mass[nd], 1.0, atol=1e-4)


def test_weight_quantization_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32) * 0.1
    qt = quantize_weights(w, bits=8)
    err = np.abs(np.asarray(dequantize(qt)) - np.asarray(w)).max()
    assert err <= float(qt.scale.max()) + 1e-7   # one quantization step


def test_truncate_to_grid_is_paper_policy():
    x = jnp.asarray([0.299999, -0.299999, 1.5, -1.5])
    got = np.asarray(truncate_to_grid(x, 2))   # grid 0.25
    np.testing.assert_array_equal(got, [0.25, -0.25, 1.5, -1.5])


def test_error_feedback_quantizer_tree():
    q = ErrorFeedbackQuantizer(frac_bits=6)
    grads = {"a": jnp.asarray([0.1, 0.2]), "b": jnp.asarray([[0.33]])}
    res = q.init_state(grads)
    comp, res2 = q.compress(grads, res)
    for k in grads:
        np.testing.assert_allclose(
            np.asarray(comp[k] + res2[k]), np.asarray(grads[k]), atol=1e-7)


def test_coo_beats_csr_gang_on_powerlaw():
    """Paper §3: COO stream utilization is degree-independent; row-gang CSR
    stalls on power-law degree skew."""
    from repro.core.csr_compare import format_comparison
    from repro.graphs import erdos_renyi, holme_kim_powerlaw

    pl_g = holme_kim_powerlaw(2000, m=8, seed=0)
    c = format_comparison(pl_g)
    assert c["coo_utilization"] > 0.9
    assert c["csr_gang_utilization"] < 0.7     # skew stalls the gang
    assert c["csr_sorted_utilization"] > c["csr_gang_utilization"]
    # uniform-degree graph: CSR gang is fine — the argument is about skew
    er = erdos_renyi(2000, 16000, seed=1)
    assert format_comparison(er)["csr_gang_utilization"] > \
        c["csr_gang_utilization"]
