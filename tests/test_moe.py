"""MoE: COO-form dispatch vs per-token dense expert evaluation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models.moe import _capacity, init_moe, moe_ffn, router_aux_loss


def _cfg(cap=8.0):
    return dataclasses.replace(
        smoke_config(get_config("mixtral-8x7b")), moe_capacity_factor=cap,
        compute_dtype="float32")


def _dense_reference(x, p, cfg):
    """Per-token: route, evaluate chosen experts densely, combine."""
    b, s, d = x.shape
    logits = x @ p["router"]
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    tv, ti = jax.lax.top_k(gates, cfg.experts_per_token)
    tv = tv / tv.sum(-1, keepdims=True)
    out = np.zeros((b, s, d), np.float32)
    xn, tvn, tin = map(np.asarray, (x, tv, ti))
    wg, wu, wd = map(np.asarray, (p["w_gate"], p["w_up"], p["w_down"]))
    for bi in range(b):
        for si in range(s):
            acc = np.zeros(d, np.float32)
            for j in range(cfg.experts_per_token):
                e = int(tin[bi, si, j])
                h = jax.nn.silu(xn[bi, si] @ wg[e]) * (xn[bi, si] @ wu[e])
                acc += tvn[bi, si, j] * np.asarray(h @ wd[e])
            out[bi, si] = acc
    return out


def test_moe_matches_dense_reference():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 10, cfg.d_model)), jnp.float32)
    got = np.asarray(moe_ffn(x, p, cfg))
    want = _dense_reference(x, p, cfg)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_capacity_drops_tokens():
    """With capacity_factor → tiny, overflow expert-slots are dropped (output
    loses those contributions) but nothing is corrupted."""
    cfg = _cfg(cap=0.25)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 16, cfg.d_model)), jnp.float32)
    out = np.asarray(moe_ffn(x, p, cfg))
    assert np.isfinite(out).all()
    dense = _dense_reference(x, p, cfg)
    row_match = np.isclose(out, dense, rtol=2e-4, atol=2e-5).all(-1)
    assert not row_match.all(), "tiny capacity must actually drop contributions"
    # dropped contributions only ever REMOVE expert outputs: with generous
    # capacity the exact dense result comes back
    out_full = np.asarray(moe_ffn(x, p, cfg, capacity_factor=8.0))
    np.testing.assert_allclose(out_full, dense, rtol=2e-4, atol=2e-5)


def test_capacity_formula():
    cfg = _cfg()
    assert _capacity(1, cfg, 1.0) >= 1
    assert _capacity(1024, cfg, 1.25) <= 1024


def test_router_aux_loss_bounds():
    cfg = _cfg()
    p = init_moe(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    aux = float(router_aux_loss(x, p, cfg))
    assert aux >= 1.0 - 1e-3  # E·Σ f·P ≥ 1 with equality at perfect balance
