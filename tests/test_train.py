"""Training substrate: optimizer math, loss decrease, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model
from repro.training import AdamWConfig, init_train_state, make_train_step
from repro.training.optimizer import adamw_update, init_opt_state, schedule


def test_adamw_on_quadratic():
    """AdamW drives a quadratic to its (decoupled-decay-shifted) optimum."""
    cfg = AdamWConfig(lr=0.05, warmup_steps=1, total_steps=500, weight_decay=0.0,
                      clip_norm=100.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return adamw_update(cfg, grads, state, params)

    for _ in range(300):
        params, state, m = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6          # end of warmup
    assert lrs[3] < lrs[2]                   # decaying
    assert abs(lrs[4] - 0.1) < 1e-6          # floor


def test_microbatching_equals_full_batch():
    """Gradient accumulation is exact: m=2 microbatches == one big batch."""
    cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                              compute_dtype="float32", num_layers=2,
                              layer_pattern=(0, 0))
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    dcfg = DataConfig(seq_len=8, global_batch=4)
    batch = synthetic_batch(cfg, dcfg, 0)
    opt = AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=10)
    s1 = jax.jit(make_train_step(api.loss_fn, opt, microbatches=1))
    s2 = jax.jit(make_train_step(api.loss_fn, opt, microbatches=2))
    st1, m1 = s1(init_train_state(params), batch)
    st2, m2 = s2(init_train_state(params), batch)
    # losses average the same samples; params should agree to fp tolerance
    for a, b in zip(jax.tree.leaves(st1.params), jax.tree.leaves(st2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_compressed_training_converges():
    """Fixed-point gradient compression with error feedback still learns."""
    cfg = dataclasses.replace(smoke_config(get_config("gemma-2b")),
                              compute_dtype="float32", num_layers=2,
                              layer_pattern=(0, 0))
    api = build_model(cfg, remat=False)
    params = api.init_params(jax.random.PRNGKey(0))
    dcfg = DataConfig(seq_len=16, global_batch=8)
    batch = synthetic_batch(cfg, dcfg, 0)
    step = jax.jit(make_train_step(
        api.loss_fn, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30),
        grad_compress_bits=8))
    state = init_train_state(params, compress=True)
    losses = []
    for _ in range(10):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
    # residuals are bounded by the grid resolution
    rmax = max(float(jnp.abs(r).max()) for r in jax.tree.leaves(state.residual))
    assert rmax <= 2.0 ** -8 + 1e-6
