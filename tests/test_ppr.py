"""PPR correctness: float vs scipy/networkx oracles; fixed-point accuracy and
convergence claims (paper §5.3)."""
import numpy as np
import pytest

from repro.core import PPRConfig, Q1_19, Q1_25, format_for_bits, run_ppr
from repro.core.metrics import full_report
from repro.graphs import erdos_renyi, holme_kim_powerlaw, ppr_reference, watts_strogatz


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(800, m=6, seed=0)


def test_float_ppr_matches_scipy(graph):
    pers = np.array([1, 5, 9])
    ref = ppr_reference(graph, pers, iterations=60)
    got, _ = run_ppr(graph, pers, PPRConfig(iterations=60))
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_float_ppr_matches_networkx():
    import networkx as nx

    g = erdos_renyi(200, 1200, seed=4)
    G = nx.DiGraph()
    G.add_nodes_from(range(200))
    # rebuild the raw edge list from X entries (x=dst, y=src)
    G.add_edges_from(zip(g.y.tolist(), g.x.tolist()))
    # paper eq.(1) spreads dangling mass uniformly (α/|V|·d̄ᵀP·1); networkx
    # defaults to the personalization vector — make it uniform to match
    nx_scores = nx.pagerank(G, alpha=0.85, personalization={7: 1.0}, tol=1e-12,
                            max_iter=200, dangling={i: 1.0 for i in range(200)})
    got, _ = run_ppr(g, np.array([7]), PPRConfig(iterations=100))
    ours = got[:, 0]
    theirs = np.array([nx_scores[i] for i in range(200)])
    # networkx normalizes by sum; ours follows eq.(1) un-normalized — compare shapes
    np.testing.assert_allclose(ours / ours.sum(), theirs, atol=1e-6)


def test_fixed_point_ranking_quality(graph):
    """Paper Fig. 4: 26-bit fixed point ⇒ NDCG > 99.9%, top-10 edit distance ≤ 1."""
    pers = np.array([3, 11, 42, 101])
    ref = ppr_reference(graph, pers, iterations=100)
    got, _ = run_ppr(graph, pers, PPRConfig(iterations=10), fmt=Q1_25)
    reports = [full_report(got[:, i], ref[:, i]) for i in range(4)]
    ndcg = np.mean([r["ndcg"] for r in reports])
    edit10 = np.mean([r["edit@10"] for r in reports])
    assert ndcg > 0.999, f"NDCG {ndcg}"
    assert edit10 <= 1.5, f"edit@10 {edit10}"


def test_lower_bits_lower_quality(graph):
    """Paper Fig. 4 trend: accuracy decreases monotonically-ish with bit-width."""
    pers = np.array([3, 11])
    ref = ppr_reference(graph, pers, iterations=100)
    prec = {}
    for bits in (26, 20, 12):
        got, _ = run_ppr(graph, pers, PPRConfig(iterations=10),
                         fmt=format_for_bits(bits))
        prec[bits] = np.mean([full_report(got[:, i], ref[:, i])["precision@50"]
                              for i in range(2)])
    assert prec[26] >= prec[12]
    assert prec[26] > 0.9


def test_fixed_point_converges_faster(graph):
    """Paper Fig. 7: truncation creates an absorbing state — fixed-point delta
    hits exactly 0 while float is still moving."""
    pers = np.array([5])
    _, d_fixed = run_ppr(graph, pers, PPRConfig(iterations=30), fmt=Q1_19)
    _, d_float = run_ppr(graph, pers, PPRConfig(iterations=30))
    it_fixed = int(np.argmax(d_fixed == 0.0)) if (d_fixed == 0).any() else 30
    assert it_fixed < 30, "fixed point must reach its absorbing state"
    assert d_float[it_fixed] > 0.0, "float should still be converging at that point"


def test_dangling_vertices_conserve_mass():
    """Graphs with dangling vertices keep Σp ≈ 1 via the dangling term."""
    src = np.array([0, 0, 1, 2])
    dst = np.array([1, 2, 3, 3])   # vertex 3 dangles
    from repro.core.coo import COOGraph

    g = COOGraph.from_edges(src, dst, 5)   # vertex 4 isolated+dangling
    assert g.dangling[3] and g.dangling[4]
    got, _ = run_ppr(g, np.array([0]), PPRConfig(iterations=80))
    total = got[:, 0].sum()
    assert abs(total - 1.0) < 1e-3, total


def test_kappa_batching_equivalence(graph):
    """Batched κ=4 results equal κ=1 runs (the paper's batching is lossless)."""
    pers = np.array([2, 4, 6, 8])
    batched, _ = run_ppr(graph, pers, PPRConfig(iterations=15))
    for i, v in enumerate(pers):
        single, _ = run_ppr(graph, np.array([v]), PPRConfig(iterations=15))
        np.testing.assert_allclose(batched[:, i], single[:, 0], atol=1e-6)


def test_ws_and_gnp_distributions():
    """Paper Table 1: trends hold across graph distributions."""
    for gen, kw in [(erdos_renyi, dict(n=500, m=3000)),
                    (watts_strogatz, dict(n=500, k=12))]:
        g = gen(seed=1, **kw)
        ref = ppr_reference(g, np.array([0]), iterations=100)
        got, _ = run_ppr(g, np.array([0]), PPRConfig(iterations=10), fmt=Q1_25)
        rep = full_report(got[:, 0], ref[:, 0])
        assert rep["ndcg"] > 0.99
