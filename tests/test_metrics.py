"""Ranking-metric unit tests (paper §5.3.1 definitions)."""
import numpy as np
import pytest

from repro.core.metrics import (
    _kendall_tau_b,
    edit_distance,
    full_report,
    kendall_tau,
    mae,
    ndcg,
    num_errors,
    precision_at,
    ranking,
    topk_indices,
)


def _scores_for_order(order, n=None):
    """Score vector whose ranking equals `order`."""
    n = n or len(order)
    s = np.zeros(n)
    for rank, idx in enumerate(order):
        s[idx] = n - rank
    return s


def test_paper_example():
    """Paper: correct top-4 {2,4,8,6} vs retrieved {4,8,6,2} → 4 errors, edit 1."""
    ref = _scores_for_order([2, 4, 8, 6], n=10)
    approx = _scores_for_order([4, 8, 6, 2], n=10)
    assert num_errors(approx, ref, 4) == 4
    assert edit_distance(approx, ref, 4) <= 2   # 1 insertion + trailing drop
    assert precision_at(approx, ref, 4) == 1.0  # same set


def test_perfect_ranking():
    s = np.random.default_rng(0).random(100)
    assert num_errors(s, s, 10) == 0
    assert edit_distance(s, s, 20) == 0
    assert ndcg(s, s, 50) == 1.0
    assert precision_at(s, s, 10) == 1.0
    assert kendall_tau(s, s, 20) == 1.0
    assert mae(s, s) == 0.0


def test_ndcg_penalizes_top_swaps_more():
    ref = np.arange(100, dtype=float)
    top_swap = ref.copy()
    top_swap[[99, 98]] = top_swap[[98, 99]]     # swap ranks 1↔2
    bottom_swap = ref.copy()
    bottom_swap[[50, 51]] = bottom_swap[[51, 50]]
    assert ndcg(top_swap, ref, 50) < ndcg(bottom_swap, ref, 50) <= 1.0


def test_edit_distance_shift():
    ref = _scores_for_order([0, 1, 2, 3, 4], n=20)
    shifted = _scores_for_order([5, 0, 1, 2, 3], n=20)  # one insertion at front
    assert edit_distance(shifted, ref, 5) <= 2
    assert num_errors(shifted, ref, 5) == 5             # coarse metric: all moved


def test_topk_deterministic_ties():
    s = np.zeros(10)
    assert topk_indices(s, 3).tolist() == [0, 1, 2]


def test_kendall_reversal():
    ref = np.arange(50, dtype=float)
    assert abs(kendall_tau(-ref, ref, 10) - (-1.0)) < 1e-9


# ---------------------------------------------------------------------------
# edges: n > |V|, tie-breaking, the numpy τ-b fallback, precomputed orders
# ---------------------------------------------------------------------------
def test_topk_n_exceeds_num_vertices():
    s = np.random.default_rng(0).random(7)
    got = topk_indices(s, 50)
    assert got.shape == (7,)                     # clamped, not padded
    np.testing.assert_array_equal(got, ranking(s))


def test_metrics_n_exceeds_num_vertices():
    rng = np.random.default_rng(1)
    s = rng.random(7)
    assert ndcg(s, s, 50) == 1.0                 # was a shape error pre-clamp
    assert precision_at(s, s, 50) == 1.0         # was 7/50 pre-clamp
    assert num_errors(s, s, 50) == 0
    assert edit_distance(s, s, 50) == 0
    assert kendall_tau(s, s, 50) == 1.0
    noisy = s + rng.normal(0, 0.3, 7)
    assert 0.0 < ndcg(noisy, s, 50) <= 1.0       # finite on mismatch too


def test_ndcg_tie_breaking_deterministic():
    """All-tied scores rank by ascending id in both arguments, so a fully
    tied approx against a fully tied ref is a perfect (deterministic) match."""
    tied = np.zeros(20)
    assert ndcg(tied, tied, 10) == 1.0
    assert num_errors(tied, tied, 10) == 0
    # partially tied: the tied block must order by id, not by memory noise
    s = np.array([0.5, 0.2, 0.2, 0.2, 0.1])
    np.testing.assert_array_equal(topk_indices(s, 4), [0, 1, 2, 3])


def test_kendall_numpy_fallback_matches_scipy():
    st = pytest.importorskip("scipy.stats")
    rng = np.random.default_rng(2)
    for _ in range(25):
        n = int(rng.integers(2, 40))
        x = rng.integers(0, 6, n).astype(float)  # heavy ties
        y = rng.integers(0, 6, n).astype(float)
        ours = _kendall_tau_b(x, y)
        theirs = st.kendalltau(x, y)[0]
        if np.isfinite(theirs):
            assert abs(ours - theirs) < 1e-12
        else:
            assert not np.isfinite(ours)


def test_kendall_numpy_fallback_known_values():
    assert _kendall_tau_b(np.arange(5.0), np.arange(5.0)) == 1.0
    assert _kendall_tau_b(np.arange(5.0), -np.arange(5.0)) == -1.0
    assert np.isnan(_kendall_tau_b(np.ones(4), np.arange(4.0)))  # degenerate
    assert np.isnan(_kendall_tau_b(np.array([1.0]), np.array([1.0])))


def test_full_report_precomputed_reference_matches():
    rng = np.random.default_rng(3)
    ref = rng.random(200)
    approx = ref + rng.normal(0, 0.05, 200)
    assert full_report(approx, ref) == \
        full_report(approx, ref, ref_order=ranking(ref))


def test_metric_precomputed_orders_match_fresh():
    rng = np.random.default_rng(4)
    ref = rng.integers(0, 30, 120).astype(float)     # ties galore
    approx = rng.integers(0, 30, 120).astype(float)
    ao, ro = ranking(approx), ranking(ref)
    kw = {"approx_order": ao, "ref_order": ro}
    assert num_errors(approx, ref, 15, **kw) == num_errors(approx, ref, 15)
    assert edit_distance(approx, ref, 15, **kw) == edit_distance(approx, ref, 15)
    assert ndcg(approx, ref, 15, **kw) == ndcg(approx, ref, 15)
    assert precision_at(approx, ref, 15, **kw) == precision_at(approx, ref, 15)
    assert kendall_tau(approx, ref, 15, ref_order=ro) == \
        kendall_tau(approx, ref, 15)
