"""Ranking-metric unit tests (paper §5.3.1 definitions)."""
import numpy as np

from repro.core.metrics import (
    edit_distance,
    kendall_tau,
    mae,
    ndcg,
    num_errors,
    precision_at,
    topk_indices,
)


def _scores_for_order(order, n=None):
    """Score vector whose ranking equals `order`."""
    n = n or len(order)
    s = np.zeros(n)
    for rank, idx in enumerate(order):
        s[idx] = n - rank
    return s


def test_paper_example():
    """Paper: correct top-4 {2,4,8,6} vs retrieved {4,8,6,2} → 4 errors, edit 1."""
    ref = _scores_for_order([2, 4, 8, 6], n=10)
    approx = _scores_for_order([4, 8, 6, 2], n=10)
    assert num_errors(approx, ref, 4) == 4
    assert edit_distance(approx, ref, 4) <= 2   # 1 insertion + trailing drop
    assert precision_at(approx, ref, 4) == 1.0  # same set


def test_perfect_ranking():
    s = np.random.default_rng(0).random(100)
    assert num_errors(s, s, 10) == 0
    assert edit_distance(s, s, 20) == 0
    assert ndcg(s, s, 50) == 1.0
    assert precision_at(s, s, 10) == 1.0
    assert kendall_tau(s, s, 20) == 1.0
    assert mae(s, s) == 0.0


def test_ndcg_penalizes_top_swaps_more():
    ref = np.arange(100, dtype=float)
    top_swap = ref.copy()
    top_swap[[99, 98]] = top_swap[[98, 99]]     # swap ranks 1↔2
    bottom_swap = ref.copy()
    bottom_swap[[50, 51]] = bottom_swap[[51, 50]]
    assert ndcg(top_swap, ref, 50) < ndcg(bottom_swap, ref, 50) <= 1.0


def test_edit_distance_shift():
    ref = _scores_for_order([0, 1, 2, 3, 4], n=20)
    shifted = _scores_for_order([5, 0, 1, 2, 3], n=20)  # one insertion at front
    assert edit_distance(shifted, ref, 5) <= 2
    assert num_errors(shifted, ref, 5) == 5             # coarse metric: all moved


def test_topk_deterministic_ties():
    s = np.zeros(10)
    assert topk_indices(s, 3).tolist() == [0, 1, 2]


def test_kendall_reversal():
    ref = np.arange(50, dtype=float)
    assert abs(kendall_tau(-ref, ref, 10) - (-1.0)) < 1e-9
