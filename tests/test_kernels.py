"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coo import BlockedCOO, COOGraph
from repro.core.fixed_point import Q1_19, Q1_25, QFormat
from repro.core.quantization import quantize_weights
from repro.graphs import erdos_renyi
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _random_graph(v, e, seed):
    return erdos_renyi(v, e, seed=seed)


@pytest.mark.parametrize("v,e,k,v_tile,packet", [
    (256, 1024, 4, 64, 32),
    (500, 3000, 8, 128, 64),
    (1000, 8000, 16, 256, 128),
    (100, 400, 1, 128, 128),      # K=1: plain SpMV
    (64, 64, 2, 64, 32),          # single tile
])
def test_coo_spmv_float_sweep(v, e, k, v_tile, packet):
    g = _random_graph(v, e, seed=v + e)
    rng = np.random.default_rng(0)
    p = (rng.random((v, k)) / v).astype(np.float32)
    blocked = BlockedCOO.build(g, v_tile=v_tile, packet=packet)
    pp = kops.pad_p_for_blocks(jnp.asarray(p), blocked)
    out = np.asarray(kops.coo_spmv(blocked, pp, interpret=True))[:v]
    ref = np.asarray(kref.coo_spmv_ref(
        jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(g.val), jnp.asarray(p), v))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("fmt", [Q1_25, Q1_19])
def test_coo_spmv_fixed_bit_exact(fmt):
    v, e, k = 400, 2500, 8
    g = _random_graph(v, e, seed=3)
    rng = np.random.default_rng(1)
    p_raw = rng.integers(0, fmt.scale // v + 2, (v, k)).astype(np.uint32)
    blocked = BlockedCOO.build(g, v_tile=128, packet=64)
    pp = kops.pad_p_for_blocks(jnp.asarray(p_raw), blocked)
    out = np.asarray(kops.coo_spmv(blocked, pp, fmt=fmt, interpret=True))[:v]
    ref = np.asarray(kref.coo_spmv_fixed_ref(
        jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(g.quantized_val(fmt)),
        jnp.asarray(p_raw), v, fmt))
    assert (out == ref).all(), "fixed-point kernel must be bit-exact"


def test_blocked_coo_roundtrip():
    """Blocking preserves the edge multiset (local→global reconstruction)."""
    g = _random_graph(300, 2000, seed=7)
    b = BlockedCOO.build(g, v_tile=64, packet=32)
    n_src = b.n_src
    starts = b.block_starts
    xs, ys, vs = [], [], []
    for blk in range(b.n_dst * n_src):
        lo, hi = starts[blk] * b.packet, starts[blk + 1] * b.packet
        bx, by = blk // n_src, blk % n_src
        val = b.val[lo:hi]
        real = val > 0
        xs.append(b.x_local[lo:hi][real] + bx * b.v_tile)
        ys.append(b.y_local[lo:hi][real] + by * b.v_tile)
        vs.append(val[real])
    got = sorted(zip(np.concatenate(xs).tolist(), np.concatenate(ys).tolist(),
                     np.concatenate(vs).tolist()))
    want = sorted(zip(g.x.tolist(), g.y.tolist(), g.val.tolist()))
    assert got == want


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 384, 512, 128, 128, 128),
    (128, 256, 128, 64, 64, 64),
])
def test_quantized_matmul_sweep(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m + n)
    a = rng.standard_normal((m, k)).astype(np.float32)
    w = (rng.standard_normal((k, n)) * 0.05).astype(np.float32)
    qt = quantize_weights(jnp.asarray(w))
    out = kops.quantized_matmul(jnp.asarray(a), qt.q, qt.scale,
                                interpret=True, bm=bm, bn=bn, bk=bk)
    ref = kref.quantized_matmul_ref(jnp.asarray(a), qt.q, qt.scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_quantized_matmul_shape_check():
    a = jnp.zeros((100, 128), jnp.float32)
    with pytest.raises(ValueError):
        kops.quantized_matmul(a, jnp.zeros((128, 128), jnp.int8),
                              jnp.ones((128,)), interpret=True)


@given(st.integers(2, 6), st.integers(1, 8))
@settings(max_examples=10, deadline=None)
def test_coo_spmv_property_random_shapes(log_v, k):
    """Property: kernel == oracle across random graph sizes and κ widths."""
    v = 2 ** log_v * 16
    g = _random_graph(v, v * 4, seed=log_v * 10 + k)
    rng = np.random.default_rng(k)
    p = (rng.random((v, k)) / v).astype(np.float32)
    blocked = BlockedCOO.build(g, v_tile=32, packet=16)
    pp = kops.pad_p_for_blocks(jnp.asarray(p), blocked)
    out = np.asarray(kops.coo_spmv(blocked, pp, interpret=True))[:v]
    ref = np.asarray(kref.coo_spmv_ref(
        jnp.asarray(g.x), jnp.asarray(g.y), jnp.asarray(g.val), jnp.asarray(p), v))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-8)


def test_packed_indices_uint16():
    """v_tile ≤ 65536 → indices pack to uint16, halving the index stream; the
    kernel consumes the packed form bit-identically."""
    g = _random_graph(500, 3000, seed=9)
    b = BlockedCOO.build(g, v_tile=128, packet=64)
    assert b.index_dtype == np.uint16
    xp_, yp_ = b.packed_indices()
    assert xp_.dtype == np.uint16
    np.testing.assert_array_equal(xp_.astype(np.int32), b.x_local)
    # packed stream bytes: 2+2 index bytes + value
    assert b.edge_stream_bytes(32) == b.num_packets * b.packet * 8
    assert b.edge_stream_bytes(26 // 1) < b.edge_stream_bytes(32)
    big = BlockedCOO.build(g, v_tile=1 << 17, packet=64)
    assert big.index_dtype == np.int32
