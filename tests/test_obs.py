"""Observability layer: bounded instruments (histogram bucket boundaries,
reservoir eviction, label-cardinality containment), deterministic span trees
under an injected clock, flight-recorder ring wraparound, telemetry memory
boundedness, Prometheus text exposition (parsed as a scraper would), and the
acceptance e2e — a query through the real HTTP tier leaving a complete trace
retrievable from /v1/debug/traces."""
import asyncio
import math
import re

import pytest

from repro.graphs import holme_kim_powerlaw
from repro.obs import (
    Counter,
    FlightRecorder,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    Tracer,
    exponential_buckets,
    format_event,
    format_trace,
    prometheus_text,
)
from repro.ppr_serving import PPRHTTPServer, PPRQuery, PPRService
from repro.ppr_serving.http import http_request
from repro.ppr_serving.telemetry import ServiceTelemetry


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.fixture(scope="module")
def graph():
    return holme_kim_powerlaw(300, m=4, seed=2)


# ---------------------------------------------------------------------------
# instruments
# ---------------------------------------------------------------------------
def test_counter_is_monotone():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_tracks_last_and_peak():
    g = Gauge()
    g.set(3)
    g.set(9)
    g.set(1)
    assert g.value == 1.0 and g.peak == 9.0


def test_exponential_buckets_values_and_validation():
    assert exponential_buckets(1.0, 2.0, 4) == (1.0, 2.0, 4.0, 8.0)
    for bad in [(0.0, 2.0, 4), (1.0, 1.0, 4), (1.0, 2.0, 0)]:
        with pytest.raises(ValueError):
            exponential_buckets(*bad)


def test_histogram_bucket_boundaries():
    """Bounds are inclusive upper edges (Prometheus ``le`` semantics): an
    observation exactly on a bound lands in that bound's bucket."""
    h = Histogram(bounds=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 2.0, 3.0, 4.0, 100.0):
        h.observe(v)
    # per-bucket: (<=1): 0.5, 1.0 | (<=2): 2.0 | (<=4): 3.0, 4.0 | inf: 100.0
    assert h.bucket_counts == [2, 1, 2, 1]
    assert h.cumulative() == [(1.0, 2), (2.0, 3), (4.0, 5), (math.inf, 6)]
    assert h.count == 6
    assert h.sum == pytest.approx(110.5)
    assert h.mean == pytest.approx(110.5 / 6)


def test_histogram_rejects_bad_bounds():
    for bad in [(), (2.0, 1.0), (1.0, 1.0)]:
        with pytest.raises(ValueError):
            Histogram(bounds=bad)


def test_reservoir_exact_below_capacity():
    r = Reservoir(size=8)
    for v in range(5):
        r.add(float(v))
    assert r.values() == [0.0, 1.0, 2.0, 3.0, 4.0]
    assert r.n_seen == 5 and r.sum == 10.0
    assert r.percentile(50) == 2.0
    assert r.percentile(0) == 0.0 and r.percentile(100) == 4.0


def test_reservoir_eviction_is_bounded_uniform_and_deterministic():
    r = Reservoir(size=16, seed=7)
    for v in range(10_000):
        r.add(float(v))
    assert len(r.values()) == 16          # bounded
    assert r.n_seen == 10_000
    assert r.sum == float(sum(range(10_000)))   # sum stays exact
    # Algorithm R keeps a uniform sample: with 10k uniform arrivals the held
    # sample's spread must cover the stream, not just the head or tail
    vals = sorted(r.values())
    assert vals[0] < 2_500 and vals[-1] > 7_500
    # seeded: a replay holds the identical sample
    r2 = Reservoir(size=16, seed=7)
    for v in range(10_000):
        r2.add(float(v))
    assert r.values() == r2.values()


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help")
    assert reg.counter("x_total") is c
    with pytest.raises(ValueError):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("a",))


def test_registry_label_cardinality_collapses_to_overflow():
    reg = MetricsRegistry(max_series=3)
    fam = reg.counter("c_total", labels=("who",))
    for i in range(10):
        fam.labels(who=f"u{i}").inc()
    series = dict(fam.series())
    assert len(series) == 4               # 3 real + 1 overflow
    assert series[(("who", "_overflow"),)].value == 7.0


def test_labeled_family_rejects_wrong_labels_and_bare_get():
    reg = MetricsRegistry()
    fam = reg.counter("c_total", labels=("a",))
    with pytest.raises(ValueError):
        fam.labels(b="x")
    with pytest.raises(ValueError):
        fam.get()


# ---------------------------------------------------------------------------
# tracing: deterministic span trees under an injected clock
# ---------------------------------------------------------------------------
def test_span_tree_deterministic_under_fake_clock():
    clk = FakeClock()
    sink = []
    tracer = Tracer(time_fn=clk, sink=sink.append)
    tr = tracer.start("query", "query", graph="g", vertex=3)
    clk.t = 1.0
    sp = tr.span("cache_probe", clk())
    clk.t = 1.5
    sp.end(clk(), hit=False)
    clk.t = 4.0
    tracer.finish(tr, outcome="resolved")
    assert tracer.started == 1 and tracer.finished == 1
    assert [t.trace_id for t in sink] == [1]
    d = tr.to_dict()
    assert d == {
        "trace_id": 1, "kind": "query",
        "root": {
            "name": "query", "start_s": 0.0, "end_s": 4.0, "duration_s": 4.0,
            "attrs": {"graph": "g", "vertex": 3, "outcome": "resolved"},
            "children": [{"name": "cache_probe", "start_s": 1.0,
                          "end_s": 1.5, "duration_s": 0.5,
                          "attrs": {"hit": False}}],
        },
    }
    # finish is idempotent: a second completion path records nothing new
    clk.t = 99.0
    tracer.finish(tr, outcome="late")
    assert tr.root.end_s == 4.0 and len(sink) == 1

    rendered = format_trace(d)
    assert "trace 1 (query)" in rendered
    assert "cache_probe" in rendered


def test_nested_spans_render_depth():
    clk = FakeClock()
    tracer = Tracer(time_fn=clk)
    tr = tracer.start("wave", "wave")
    outer = tr.span("iterate", 0.0)
    outer.child("step", 0.1).end(0.2)
    outer.end(0.3)
    tracer.finish(tr)
    lines = format_trace(tr.to_dict()).splitlines()
    assert lines[1].startswith("  wave")
    assert lines[2].startswith("    iterate")
    assert lines[3].startswith("      step")


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------
def test_flight_recorder_ring_wraparound():
    clk = FakeClock()
    rec = FlightRecorder(trace_capacity=4, event_capacity=3)
    tracer = Tracer(time_fn=clk, sink=rec.record_trace)
    for i in range(10):
        clk.t = float(i)
        tracer.finish(tracer.start("query", "query", seq=i))
    assert rec.traces_recorded == 10
    held = rec.traces()
    assert len(held) == 4                 # ring: only the last 4 survive
    assert [t["root"]["attrs"]["seq"] for t in held] == [6, 7, 8, 9]
    assert rec.traces(2) == held[-2:]     # tail-n, oldest first

    for i in range(7):
        rec.record_event("kappa", float(i), kappa=2 ** i)
    assert rec.events_recorded == 7
    assert [e["kappa"] for e in rec.events()] == [16, 32, 64]

    snap = rec.snapshot(n_traces=1, n_events=1)
    assert snap["trace_capacity"] == 4 and snap["event_capacity"] == 3
    assert len(snap["traces"]) == 1 and len(snap["events"]) == 1
    assert "kappa" in format_event(snap["events"][0])


# ---------------------------------------------------------------------------
# telemetry: bounded memory, documented knob
# ---------------------------------------------------------------------------
def test_telemetry_memory_is_bounded_in_queries_served():
    t = ServiceTelemetry(reservoir_size=32)
    for i in range(5_000):
        t.record_wave(3, 8, 0.001 * (i + 1), "Q1.7", engine="fixed")
        t.record_shadow(0.9)
    assert t.waves == 5_000 and t.queries_served == 15_000
    # the legacy list views are reservoir-backed: bounded at the knob
    assert len(t.wave_latencies_s) == 32
    assert len(t.wave_occupancies) == 32
    assert len(t.shadow_scores) == 32
    assert len(t.wave_latencies_by_engine["fixed"]) == 32
    assert len(t.wave_precisions) == 32
    # exact lifetime aggregates survive eviction
    s = t.summary()
    assert s["waves"] == 5_000
    assert s["mean_occupancy"] == pytest.approx(3 / 8)
    assert s["shadow_quality_mean"] == pytest.approx(0.9)
    assert t.engine_stats()["fixed"]["waves"] == 5_000


def test_telemetry_record_stage_rejects_unknown_stage():
    t = ServiceTelemetry()
    with pytest.raises(ValueError):
        t.record_stage("not-a-stage", 0.1)


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?P<labels>\{[^{}]*\})? '
    r'(?P<value>[0-9eE.+-]+|\+Inf|-Inf|NaN)$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text):
    """Parse text exposition the way a scraper does: returns
    ``{family: kind}`` and ``[(name, labels_dict, value)]`` samples, raising
    on any malformed line."""
    families, samples = {}, []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(" ", 3)
            assert kind in ("counter", "gauge", "histogram", "summary"), line
            families[name] = kind
            continue
        assert not line.startswith("#"), f"unknown comment: {line}"
        m = _SAMPLE_RE.match(line)
        assert m, f"malformed sample line: {line!r}"
        labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
        samples.append((m.group("name"), labels, m.group("value")))
    for name, _, _ in samples:
        base = re.sub(r"_(bucket|sum|count)$", "", name)
        assert name in families or base in families, \
            f"sample {name} has no TYPE declaration"
    return families, samples


def test_prometheus_text_round_trips_through_a_parser():
    reg = MetricsRegistry(reservoir_size=8)
    reg.counter("ppr_waves_total", "Waves.").get().inc(5)
    reg.gauge("ppr_queue_depth", "Depth.").get().set(3)
    h = reg.histogram("ppr_lat_seconds", "Latency.", bounds=(0.1, 1.0))
    h.get().observe(0.05)
    h.get().observe(5.0)
    r = reg.reservoir("ppr_lat_q", "Sample.")
    r.get().add(1.0)
    fam = reg.counter("ppr_served_total", "Served.", labels=("precision",))
    fam.labels(precision='we"ird\\fmt\n').inc()

    families, samples = parse_prometheus(prometheus_text(reg))
    assert families["ppr_waves_total"] == "counter"
    assert families["ppr_lat_seconds"] == "histogram"
    assert families["ppr_lat_q"] == "summary"
    by_name = {}
    for name, labels, value in samples:
        by_name.setdefault(name, []).append((labels, value))
    assert by_name["ppr_waves_total"] == [({}, "5")]
    # gauges export value + running peak
    assert ({}, "3") in by_name["ppr_queue_depth"]
    assert ({}, "3") in by_name["ppr_queue_depth_peak"]
    # histogram: cumulative buckets end at +Inf == count
    les = {lab["le"]: v for lab, v in by_name["ppr_lat_seconds_bucket"]}
    assert les == {"0.1": "1", "1": "1", "+Inf": "2"}
    assert by_name["ppr_lat_seconds_count"] == [({}, "2")]
    # summary quantiles
    qs = {lab["quantile"] for lab, _ in by_name["ppr_lat_q"]}
    assert qs == {"0.5", "0.95", "0.99"}
    # label escaping survived the parse round-trip
    (labels, _), = by_name["ppr_served_total"]
    assert labels["precision"] == r'we\"ird\\fmt\n'


def test_service_registry_exports_all_families_without_traffic():
    """Every pre-declared family exports (zero-valued) before any wave runs —
    dashboards see stable series from first scrape."""
    t = ServiceTelemetry()
    families, samples = parse_prometheus(prometheus_text(t.registry))
    assert "ppr_waves_total" in families
    assert "ppr_wave_stage_seconds" in families
    assert "ppr_admission_wait_seconds" in families
    assert ("ppr_waves_total", {}, "0") in samples


# ---------------------------------------------------------------------------
# acceptance e2e: trace + metrics through the real HTTP tier
# ---------------------------------------------------------------------------
def test_e2e_http_trace_and_prometheus_wire(graph):
    """One query through the real asyncio HTTP server yields (a) a complete
    query trace and its wave trace retrievable from GET /v1/debug/traces,
    and (b) a GET /v1/metrics body that parses as Prometheus text."""
    svc = PPRService(kappa=2, iterations=4, max_wait=0.001, tracing=True)
    svc.register_graph("g", graph, formats=[16])
    server = PPRHTTPServer(svc, pump_interval_s=0.005)

    async def scenario():
        await server.start()
        try:
            host, port = server.host, server.port
            status, _, rec = await http_request(
                host, port, "POST", "/v1/ppr",
                {"graph": "g", "vertex": 5, "k": 4, "precision": "Q1.15"})
            assert status == 200
            assert len(rec["recommendations"]) == 4

            status, headers, body = await http_request(
                host, port, "GET", "/v1/metrics")
            assert status == 200
            assert headers["content-type"].startswith("text/plain")
            families, samples = parse_prometheus(body)
            assert families["ppr_waves_total"] == "counter"
            assert ("ppr_waves_total", {}, "1") in samples
            assert "ppr_pump_cycles_total" in families
            stage_counts = {lab["stage"]: v for name, lab, v in samples
                            if name == "ppr_wave_stage_seconds_count"}
            assert stage_counts.get("iterate") == "1"

            status, _, js = await http_request(
                host, port, "GET", "/v1/metrics?format=json")
            assert status == 200
            assert js["ppr_waves_total"] == 1

            status, _, snap = await http_request(
                host, port, "GET", "/v1/debug/traces?n=10")
            assert status == 200 and snap["tracing"]
            return snap
        finally:
            await server.stop()

    snap = asyncio.run(scenario())
    traces = {t["kind"]: t for t in snap["traces"]}
    assert set(traces) == {"query", "wave"}
    q, w = traces["query"], traces["wave"]
    # the complete query trace: precision resolution, cache probe, admission
    # wait, wave execution — finished, linked to its wave
    names = [c["name"] for c in q["root"]["children"]]
    assert names == ["resolve_precision", "cache_probe", "admission_wait",
                     "wave_execute"]
    assert q["root"]["attrs"]["outcome"] == "resolved"
    assert q["root"]["attrs"]["wave_trace"] == w["trace_id"]
    assert q["root"]["end_s"] is not None
    # and the wave side: stage spans + the member link back
    wnames = [c["name"] for c in w["root"]["children"]]
    assert wnames == ["plan", "warm_start", "iterate", "topk", "resolve"]
    assert q["trace_id"] in w["root"]["attrs"]["member_traces"]
    it = dict(w["root"]["children"][2]["attrs"])
    assert it["iterations_run"] == 4 and it["budget"] == 4


# ---------------------------------------------------------------------------
# trace head-sampling (PPRService(tracing=<rate>))
# ---------------------------------------------------------------------------
def test_tracing_accepts_bool_and_rate_and_validates(graph):
    assert PPRService(kappa=2, iterations=2, tracing=False).tracer is None
    assert PPRService(kappa=2, iterations=2, tracing=0.0).tracer is None
    assert PPRService(kappa=2, iterations=2, tracing=True).tracer is not None
    assert PPRService(kappa=2, iterations=2, tracing=0.5).tracer is not None
    for bad in (-0.1, 1.5):
        with pytest.raises(ValueError):
            PPRService(kappa=2, iterations=2, tracing=bad)


def test_head_sampling_traces_a_seeded_subset(graph):
    """tracing=0.5 head-samples per query with a seeded RNG: a deterministic
    subset of queries carries traces, each tagged with the decision rate;
    sampled-out queries pay one RNG draw and record nothing."""
    svc = PPRService(kappa=2, iterations=3, max_wait=100.0, tracing=0.5)
    svc.register_graph("g", graph, formats=[16])
    n = 12
    for v in range(n):
        svc.submit(PPRQuery("g", v, k=4, precision="Q1.15"))
    svc.flush()
    queries = [t for t in svc.recorder.traces() if t["kind"] == "query"]
    assert 0 < len(queries) < n            # a strict subset at rate 0.5
    assert all(t["root"]["attrs"]["sample_rate"] == 0.5 for t in queries)
    # deterministic across runs: same seed, same subset
    svc2 = PPRService(kappa=2, iterations=3, max_wait=100.0, tracing=0.5)
    svc2.register_graph("g", graph, formats=[16])
    for v in range(n):
        svc2.submit(PPRQuery("g", v, k=4, precision="Q1.15"))
    svc2.flush()
    verts = lambda s: [t["root"]["attrs"]["vertex"]
                       for t in s.recorder.traces()
                       if t["kind"] == "query"]
    assert verts(svc) == verts(svc2)


def test_tracing_true_still_traces_every_query_without_rate_attr(graph):
    """The bool API is byte-compatible: tracing=True samples everything and
    adds no sample_rate attribute (pre-sampling trace dicts round-trip)."""
    svc = PPRService(kappa=2, iterations=3, max_wait=100.0, tracing=True)
    svc.register_graph("g", graph, formats=[16])
    for v in range(4):
        svc.submit(PPRQuery("g", v, k=4, precision="Q1.15"))
    svc.flush()
    queries = [t for t in svc.recorder.traces() if t["kind"] == "query"]
    assert len(queries) == 4
    assert all("sample_rate" not in t["root"]["attrs"] for t in queries)
