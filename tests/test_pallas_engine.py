"""Pallas-fused engine family: bit-exact parity against the composed
datapaths, delta re-packetization equivalence, early-exit driver parity,
and end-to-end serving through PPRService with engine="pallas".

Everything here runs the kernels under ``interpret=True`` (the default on
CPU-only hosts), so the suite is meaningful without a TPU."""
import numpy as np
import pytest

pytest.importorskip("jax.experimental.pallas")

import jax.numpy as jnp  # noqa: E402

from repro.autotune.convergence import ConvergencePolicy, run_until_converged  # noqa: E402
from repro.core.coo import COOGraph  # noqa: E402
from repro.core.fixed_point import format_for_bits  # noqa: E402
from repro.graph_updates.delta import EdgeDelta  # noqa: E402
from repro.kernels.fused_ppr import build_fused_layout  # noqa: E402
from repro.ppr_serving import (  # noqa: E402
    PallasRegisteredGraph,
    PPRQuery,
    PPRService,
    get_engine,
)

ALPHA = 0.85
FMT = format_for_bits(20)
# prime V: the trailing vertex block is ragged, dangling tail included
V_PRIME = 641


def _graph(v=V_PRIME, e=2500, seed=0):
    rng = np.random.default_rng(seed)
    # sources capped below v-40 ⇒ the tail vertices are dangling
    return COOGraph.from_edges(rng.integers(0, v - 40, e),
                               rng.integers(0, v, e), v)


def _pallas_rg(g, **kw):
    kw.setdefault("packet", 64)
    kw.setdefault("v_tile", 128)     # multi-block on the prime-V test graphs
    return PallasRegisteredGraph("g", g, **kw)


def _drive(plan, pers, iterations):
    Vmat = plan.initial(jnp.asarray(pers, jnp.int32))
    P, iters = plan.iterate(lambda P_: plan.step(Vmat, P_), Vmat)
    return P, iters


def test_fixed_raw_uint32_parity_with_fixed_engine():
    g = _graph()
    pers = [5, 123, 640, 7]
    ref_rg = get_engine("float").make_graph("g", g)
    ref = get_engine("fixed").plan(ref_rg, FMT, alpha=ALPHA, iterations=8)
    pal = get_engine("pallas_fixed").plan(_pallas_rg(g), FMT, alpha=ALPHA,
                                          iterations=8)
    P_ref, _ = _drive(ref, pers, 8)
    P_pal, _ = _drive(pal, pers, 8)
    assert P_pal.dtype == jnp.uint32
    assert bool(jnp.array_equal(P_pal, P_ref))          # raw-bit equality


def test_float_parity_within_1e6():
    g = _graph(seed=3)
    pers = [1, 2, 3, 600]
    ref_rg = get_engine("float").make_graph("g", g)
    ref = get_engine("float").plan(ref_rg, alpha=ALPHA, iterations=8)
    pal = get_engine("pallas_float").plan(_pallas_rg(g), alpha=ALPHA,
                                          iterations=8)
    P_ref, _ = _drive(ref, pers, 8)
    P_pal, _ = _drive(pal, pers, 8)
    assert float(jnp.abs(P_pal - P_ref).max()) < 1e-6


def test_early_exit_parity_with_run_until_converged():
    # tiny absorbing graph: the fixed path hits a strict fixed point or a
    # period-2 cycle well inside the budget; the fused driver must return the
    # same state bit-for-bit AND the same iteration count
    g = _graph(v=97, e=300, seed=5)
    pers = [0, 9, 96]
    pol = ConvergencePolicy(min_iterations=2, check_every=1)
    budget = 80
    ref_rg = get_engine("float").make_graph("g", g)
    ref = get_engine("fixed").plan(ref_rg, FMT, alpha=ALPHA, iterations=budget)
    Vref = ref.initial(jnp.asarray(pers, jnp.int32))
    P_ref, iters_ref, _ = run_until_converged(
        lambda P_: ref.step(Vref, P_), Vref, budget, pol,
        fixed=True, scale=FMT.scale, track_deltas=False)
    pal = get_engine("pallas_fixed").plan(
        _pallas_rg(g, v_tile=64), FMT, alpha=ALPHA, iterations=budget,
        convergence=pol)
    P_pal, iters_pal = _drive(pal, pers, budget)
    assert iters_pal < budget                            # actually exited early
    assert iters_pal == iters_ref
    assert bool(jnp.array_equal(P_pal, P_ref))


def test_delta_repacketization_equals_fresh_registration():
    g = _graph(seed=7)
    rg = _pallas_rg(g)
    rg.fused_topology()
    rg.fused_values(FMT)
    rg.fused_values(None)
    delta = EdgeDelta(add_src=[3, 3, 500], add_dst=[640, 11, 2],
                      remove_src=[int(g.y[0]), int(g.y[5])],
                      remove_dst=[int(g.x[0]), int(g.x[5])])
    rg.apply_delta(delta)
    for eng_key in ("pallas_float", "pallas_fixed"):
        get_engine(eng_key).on_delta(rg, None)           # idempotent latch
    fresh = _pallas_rg(rg.source)
    lay, flay = rg.fused_layout(), fresh.fused_layout()
    for field in ("x2", "y2", "val2", "step_row", "step_dst", "step_src",
                  "step_first", "step_last"):
        assert np.array_equal(getattr(lay, field), getattr(flay, field)), field
    assert np.array_equal(np.asarray(rg.fused_values(FMT)),
                          np.asarray(fresh.fused_values(FMT)))
    assert np.array_equal(np.asarray(rg.fused_values(None)),
                          np.asarray(fresh.fused_values(None)))
    # and the incremental build only rebuilt the dirty dst blocks: clean
    # blocks must be the same host arrays, not equal copies
    dirty = set(np.unique(
        np.concatenate([[640, 11, 2], [int(g.x[0]), int(g.x[5])]])
        // rg.v_tile).tolist())
    kept = [d for d in range(lay.n_blk) if d not in dirty]
    assert kept, "test graph must leave at least one clean block"


def test_delta_vertex_growth_forces_full_rebuild():
    g = _graph(v=100, e=300, seed=11)
    rg = _pallas_rg(g, v_tile=64)
    rg.fused_values(FMT)
    assert rg.fused_layout().n_blk == 2
    rg.apply_delta(EdgeDelta(add_src=[1], add_dst=[199],
                             new_num_vertices=200))
    get_engine("pallas_fixed").on_delta(rg, None)
    lay = rg.fused_layout()
    assert lay.n_blk == 4 and lay.num_vertices == 200
    fresh = _pallas_rg(rg.source, v_tile=64)
    assert np.array_equal(lay.x2, fresh.fused_layout().x2)
    assert np.array_equal(np.asarray(rg.fused_values(FMT)),
                          np.asarray(fresh.fused_values(FMT)))


def test_service_end_to_end_bit_identical():
    g = _graph(seed=1)

    def serve(engine):
        svc = PPRService(kappa=4, iterations=6, cache_capacity=0)
        svc.register_graph("g", g, formats=[20], engine=engine)
        futs = [svc.submit(PPRQuery("g", v, k=5, precision=20))
                for v in (1, 7, 123, 640)]
        svc.flush()
        return [f.result() for f in futs]

    for ra, rb in zip(serve("single"), serve("pallas")):
        assert np.array_equal(ra.vertices, rb.vertices)
        assert np.array_equal(ra.scores, rb.scores)


def test_service_delta_then_serve_stays_bit_identical():
    g = _graph(seed=2)
    delta = EdgeDelta(add_src=[4, 9], add_dst=[77, 640])

    def serve(engine):
        svc = PPRService(kappa=2, iterations=5, cache_capacity=0)
        svc.register_graph("g", g, formats=[20], engine=engine)
        svc.apply_delta("g", delta)
        futs = [svc.submit(PPRQuery("g", v, k=5, precision=20))
                for v in (4, 640)]
        svc.flush()
        return [f.result() for f in futs]

    for ra, rb in zip(serve("single"), serve("pallas")):
        assert np.array_equal(ra.vertices, rb.vertices)
        assert np.array_equal(ra.scores, rb.scores)


def test_service_float_waves_serve_through_pallas():
    g = _graph(seed=4)
    svc = PPRService(kappa=4, iterations=6, cache_capacity=0)
    svc.register_graph("g", g, engine="pallas")
    f = svc.submit(PPRQuery("g", 3, k=5, precision=None))
    svc.flush()
    rec = f.result()
    assert rec.vertices.shape == (5,)
    assert np.all(np.isfinite(rec.scores))
    summ = svc.telemetry.summary()
    assert any("pallas_float" in str(k) for k in summ)


def test_pallas_family_rejects_mesh():
    svc = PPRService()
    with pytest.raises(ValueError):
        svc.register_graph("g", _graph(v=50, e=100), engine="pallas",
                           mesh=object())


def test_layout_covers_every_edge_once():
    g = _graph(seed=9)
    lay = build_fused_layout(g, 128, 64)
    real = sum(int((r != 0).sum()) for r in lay.row_val)
    # zero-valued real edges can't exist (stochastic normalization > 0)
    assert real == g.num_edges
    assert lay.step_row.shape == lay.step_dst.shape
    assert int(lay.step_first.sum()) == lay.n_blk  # one zero per dst block
    assert int(lay.step_last.sum()) == lay.n_blk   # one combine per dst block
