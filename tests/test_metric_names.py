"""Metric-name snapshot: every metric family a fresh serving stack declares,
asserted against a checked-in manifest — renaming or dropping a metric breaks
dashboards and alert rules silently, so it must be an explicit diff in review
(the mirror of tests/test_api_surface.py for the telemetry surface).

The manifest is built from a traffic-free ``ServiceTelemetry`` (every family
is pre-declared in ``reset()`` — see test_obs.py's zero-traffic export test)
plus the pump's counters, each line ``name kind [labels]``.

Regenerate after an *intentional* metric change:

    PYTHONPATH=src python tests/test_metric_names.py --write
"""
import difflib
import os
import sys

MANIFEST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "metric_names.txt")


def build_manifest() -> str:
    from repro.ppr_serving.telemetry import ServiceTelemetry

    from repro.obs import OTLPExporter, SLOMonitor, default_slo_specs

    registry = ServiceTelemetry().registry
    # the pump registers its heartbeat counters against the same registry at
    # construction; declare them here so the manifest covers the full stack
    registry.counter("ppr_pump_cycles_total", "Pump heartbeat cycles run.")
    registry.counter("ppr_pump_waves_launched_total",
                     "Waves launched from pump cycles (incl. the stop flush).")
    # the SLO monitor and OTLP exporter register their slo_*/otlp_* families
    # against the same registry when attached (PPRService(slo=..., otlp=...))
    SLOMonitor(registry, default_slo_specs())
    OTLPExporter("http://localhost:4318", transport=lambda url, body: None,
                 registry=registry)

    lines = [
        "# Metric families of the PPR serving stack (generated — do not edit).",
        "# Regenerate after an intentional metric change:",
        "#   PYTHONPATH=src python tests/test_metric_names.py --write",
        "",
    ]
    for name, kind, _help, _series in registry.collect():
        fam = registry._families[name]
        label_part = (" {" + ",".join(fam.label_names) + "}"
                      if fam.label_names else "")
        lines.append(f"{name} {kind}{label_part}")
    return "\n".join(lines) + "\n"


def test_metric_names_match_manifest():
    current = build_manifest()
    assert os.path.exists(MANIFEST), (
        f"missing metric-name manifest {MANIFEST} — generate it with "
        f"'PYTHONPATH=src python tests/test_metric_names.py --write'")
    with open(MANIFEST) as f:
        committed = f.read()
    if current != committed:
        diff = "\n".join(difflib.unified_diff(
            committed.splitlines(), current.splitlines(),
            fromfile="committed manifest", tofile="current metrics",
            lineterm=""))
        raise AssertionError(
            "the serving stack's metric names drifted from the committed "
            "manifest — that silently breaks dashboards and alert rules.  "
            "If the change is intentional, regenerate with "
            "'PYTHONPATH=src python tests/test_metric_names.py --write' and "
            "commit the diff.\n" + diff)


if __name__ == "__main__":
    if "--write" in sys.argv:
        with open(MANIFEST, "w") as f:
            f.write(build_manifest())
        print(f"wrote {MANIFEST}")
    else:
        print(build_manifest(), end="")
