#!/usr/bin/env bash
# CI entry point: tier-1 suite + the full dry-run benchmark sweep.
#   scripts/ci.sh
#
# The benchmark sweep writes BENCH_<section>.json baselines into the repo
# root (committed), so every PR leaves a machine-readable point on the perf
# trajectory — including the sharded-serving section.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== snapshot manifests (API surface + metric names) =="
# both also ride the pytest run above; re-run standalone so a drifted
# manifest fails loudly here with the regen command in the diff output
python -m pytest -q tests/test_api_surface.py tests/test_metric_names.py

echo "== static analysis: repro.analysis --check (findings report committed) =="
# the analyzer gates on any unbaselined finding OR stale baseline entry; the
# JSON report is a committed artifact so every PR carries its findings ledger
python -m repro.analysis --check --json ANALYSIS_findings.json

echo "== static analysis: negative self-test (one injected violation per pack) =="
# the gate is only trustworthy if it demonstrably FAILS on bad code: inject
# one violation per rule pack into a scratch tree and require nonzero exit
selftest="$(mktemp -d)"
trap 'rm -rf "$selftest"' EXIT
cat > "$selftest/fxp_bad.py" <<'EOF'
def combine(a_raw, b_raw):
    return a_raw * b_raw
EOF
cat > "$selftest/jax_bad.py" <<'EOF'
@jax.jit
def step(x):
    return float(x)
EOF
cat > "$selftest/asy_bad.py" <<'EOF'
async def run(self):
    self.service.poll()
EOF
for bad in fxp_bad.py jax_bad.py asy_bad.py; do
    if python -m repro.analysis "$selftest/$bad" --root "$selftest" \
            > /dev/null 2>&1; then
        echo "FATAL: analyzer passed injected violation $bad" >&2
        exit 1
    fi
done
echo "analyzer correctly rejected all 3 injected violations"

echo "== OTLP loopback smoke (stub collector, nonzero exit on drops) =="
# the exporter's default urllib transport against a real (loopback) HTTP
# sink: every queued span must arrive, the delta metrics push must land,
# and nothing may drop or fail — the wire path the unit tests inject around
python - <<'EOF'
import json
import sys
import threading
from http.server import BaseHTTPRequestHandler, HTTPServer

from repro.obs import MetricsRegistry, OTLPExporter, Tracer

hits = {"spans": 0, "metric_pushes": 0}


class Sink(BaseHTTPRequestHandler):
    def do_POST(self):
        payload = json.loads(
            self.rfile.read(int(self.headers.get("Content-Length", 0))))
        if self.path == "/v1/traces":
            hits["spans"] += sum(
                len(ss["spans"]) for rs in payload["resourceSpans"]
                for ss in rs["scopeSpans"])
        elif self.path == "/v1/metrics":
            hits["metric_pushes"] += 1
        self.send_response(200)
        self.send_header("Content-Length", "2")
        self.end_headers()
        self.wfile.write(b"{}")

    def log_message(self, *args):
        pass


collector = HTTPServer(("127.0.0.1", 0), Sink)
threading.Thread(target=collector.serve_forever, daemon=True).start()

reg = MetricsRegistry()
exp = OTLPExporter(f"http://127.0.0.1:{collector.server_port}",
                   registry=reg, max_batch=8)
tracer = Tracer(sink=exp.record_trace)
for i in range(32):
    tr = tracer.start("query", "query", vertex=i)
    tr.span("wave", 0.0).end(0.001)
    tracer.finish(tr)
reg.counter("smoke_total", "Loopback smoke traffic.").get().inc(3)
exp.flush(reg)
collector.shutdown()

s = exp.stats()
print(f"otlp smoke: {s['spans_exported']} spans / "
      f"{s['span_batches_sent']} batches delivered, "
      f"{s['metric_pushes']} metric pushes, "
      f"{s['spans_dropped']} dropped, {s['send_failures']} send failures")
ok = (s["spans_exported"] == 64 and hits["spans"] == 64
      and s["metric_pushes"] >= 1 and hits["metric_pushes"] >= 1
      and s["spans_dropped"] == 0 and s["send_failures"] == 0
      and s["queue_depth"] == 0)
sys.exit(0 if ok else 1)
EOF

echo "== examples smoke (ported to the futures API, deprecation-clean) =="
# the ported examples must not touch the deprecated serve()/pump()/drain()
# wrappers — the warning is attributed to the calling frame (stacklevel), so
# scoping the filter to __main__ catches exactly the example's own usage
# without tripping on unrelated import-time warnings from jax/numpy
python -W error::DeprecationWarning:__main__ examples/quickstart.py
python -W error::DeprecationWarning:__main__ examples/http_serving.py

echo "== pallas engine family (interpret mode; skipped if pallas unavailable) =="
# the fused-kernel suite runs under interpret=True so it is meaningful on
# CPU-only CI hosts; a host whose jax build lacks pallas skips cleanly
# (probe exit 3 = ImportError), anything else fails the gate
pallas_rc=0
python - <<'EOF' || pallas_rc=$?
import sys
try:
    import jax.experimental.pallas  # noqa: F401
except ImportError:
    sys.exit(3)
EOF
if [ "$pallas_rc" -eq 0 ]; then
    python -m pytest -q tests/test_pallas_engine.py
elif [ "$pallas_rc" -eq 3 ]; then
    echo "skip: jax.experimental.pallas not importable on this host"
else
    echo "FATAL: pallas probe failed with unexpected status $pallas_rc" >&2
    exit 1
fi

echo "== smoke + baselines: benchmark sweep (dry run, JSON into repo root) =="
# --check gates the sweep: every ran section must leave a fresh parseable
# non-empty BENCH_<section>.json, and a skipped section must not leave a
# stale baseline behind
python -m benchmarks.run --dry-run --json . --check
