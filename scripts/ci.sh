#!/usr/bin/env bash
# CI entry point: tier-1 suite + the serving smoke benchmark.
#   scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: PPRService benchmark (dry run) =="
python benchmarks/bench_serving_ppr.py --dry-run

echo "== smoke: adaptive-precision benchmark (dry run) =="
python benchmarks/bench_autotune.py --dry-run
