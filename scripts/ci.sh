#!/usr/bin/env bash
# CI entry point: tier-1 suite + the full dry-run benchmark sweep.
#   scripts/ci.sh
#
# The benchmark sweep writes BENCH_<section>.json baselines into the repo
# root (committed), so every PR leaves a machine-readable point on the perf
# trajectory — including the sharded-serving section.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== snapshot manifests (API surface + metric names) =="
# both also ride the pytest run above; re-run standalone so a drifted
# manifest fails loudly here with the regen command in the diff output
python -m pytest -q tests/test_api_surface.py tests/test_metric_names.py

echo "== examples smoke (ported to the futures API, deprecation-clean) =="
# the ported examples must not touch the deprecated serve()/pump()/drain()
# wrappers — the warning is attributed to the calling frame (stacklevel), so
# scoping the filter to __main__ catches exactly the example's own usage
# without tripping on unrelated import-time warnings from jax/numpy
python -W error::DeprecationWarning:__main__ examples/quickstart.py
python -W error::DeprecationWarning:__main__ examples/http_serving.py

echo "== smoke + baselines: benchmark sweep (dry run, JSON into repo root) =="
# --check gates the sweep: every ran section must leave a fresh parseable
# non-empty BENCH_<section>.json, and a skipped section must not leave a
# stale baseline behind
python -m benchmarks.run --dry-run --json . --check
