"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch gemma-2b]

Uses a ~100M-class reduction of the chosen architecture (real vocab, fewer/
narrower layers), the deterministic synthetic pipeline, AdamW, microbatched
gradient accumulation, and periodic async checkpoints — the full training
substrate on one CPU device.  (On a real pod, launch/train.py runs the full
config with the production mesh.)
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import FULL_ATTN
from repro.data import DataConfig, synthetic_batch
from repro.models import build_model
from repro.training import (
    AdamWConfig, FaultConfig, init_train_state, make_train_step, run_resumable,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    base = get_config(args.arch)
    # ~100M params: 4 layers × d_model 512 with the arch's real vocab
    cfg = dataclasses.replace(
        base, num_layers=4, layer_pattern=(FULL_ATTN,) * 4, d_model=512,
        num_heads=8, num_kv_heads=max(1, min(base.num_kv_heads, 8)), head_dim=64,
        d_ff=2048, compute_dtype="float32",
    )
    api = build_model(cfg, remat=True)
    print(f"{cfg.name}-100M: {cfg.param_count():,} params (analytic)")

    step_fn = jax.jit(make_train_step(
        api.loss_fn,
        AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
        microbatches=2))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch)

    def init_state():
        return init_train_state(api.init_params(jax.random.PRNGKey(0)))

    t0 = time.time()
    log = []

    def on_metrics(s, m):
        log.append(float(m["loss"]))
        if s % 20 == 0:
            tps = args.batch * args.seq * len(log) / (time.time() - t0)
            print(f"step {s:4d}  loss {log[-1]:.4f}  tok/s {tps:,.0f}", flush=True)

    fault = FaultConfig(ckpt_dir="/tmp/repro_example_train", save_every=100,
                        max_steps=args.steps)
    state, n, _ = run_resumable(fault, init_state, step_fn,
                                lambda s: synthetic_batch(cfg, dcfg, s), on_metrics)
    print(f"ran {n} steps; loss {log[0]:.3f} → {log[-1]:.3f}")
    assert log[-1] < log[0], "loss should decrease"


if __name__ == "__main__":
    main()
