"""Serve a small model with batched requests (the paper's κ-batching for LMs).

    PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.serving import Request, ServingEngine

cfg = dataclasses.replace(smoke_config(get_config("mixtral-8x7b")),
                          compute_dtype="float32")
api = build_model(cfg, remat=False)
params = api.init_params(jax.random.PRNGKey(0))
engine = ServingEngine(api, params, batch_size=4, max_len=64)

rng = np.random.default_rng(0)
requests = [
    Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
            max_new_tokens=6)
    for i in range(10)
]
t0 = time.time()
results = engine.serve(requests)
dt = time.time() - t0
n_tok = sum(len(v) for v in results.values())
print(f"MoE serving: {len(requests)} requests → {n_tok} tokens in {dt:.2f}s "
      f"({n_tok/dt:.1f} tok/s on 1 CPU)")
for uid in sorted(results)[:3]:
    print(f"  request {uid}: tokens {results[uid]}")
