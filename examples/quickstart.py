"""Quickstart: serve reduced-precision PPR recommendations and absorb live
graph updates — the paper's architecture operated as the recommender service
it was built for.

    PYTHONPATH=src python examples/quickstart.py

register → serve (κ-batched waves, bit-exact Q1.25 fixed point, top-K) →
apply_delta (epoch-versioned edge ingestion, scoped invalidation, warm-start
re-convergence) → serve again.
"""
import numpy as np

from repro.graph_updates import EdgeDelta
from repro.graphs import holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService

# 1. a social-network-like graph (Holme–Kim powerlaw, paper Table 1)
g = holme_kim_powerlaw(2000, m=6, seed=0)
print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} sparsity={g.sparsity:.1e}")

# 2. a serving instance: κ-batched waves, early-exit at the fixed-point
#    absorbing state (paper Fig. 7), warm-start seeds across graph updates
svc = PPRService(kappa=4, iterations=40, early_exit=True, warm_start=True)
svc.register_graph("social", g, formats=[26])       # pre-quantize Q1.25

users = [17, 42, 1337, 1999]
for rec in svc.serve([PPRQuery("social", u, k=5, precision=26) for u in users]):
    print(f"user {rec.query.vertex:5d}: top-5 recs {rec.vertices.tolist()} "
          f"({rec.precision}, {rec.source})")

# 3. a follower burst arrives: one new user joins (vertex growth) and follows
#    two existing users, one of whom follows back — absorbed in place, no
#    re-registration: only cache entries near the change are invalidated
delta = EdgeDelta(add_src=[2000, 2000, 17], add_dst=[17, 42, 2000],
                  new_num_vertices=2001)
report = svc.apply_delta("social", delta)
print(f"delta applied in {report['apply_s']*1e3:.1f} ms: epoch {report['epoch']}, "
      f"|V| -> {report['num_vertices']}, cache dropped {report['cache_dropped']} "
      f"/ retained {report['cache_retained']} (frontier {report['frontier_size']})")

# 4. serve the updated graph — invalidated users recompute (warm-started from
#    their pre-delta converged state, so the wave early-exits sooner),
#    untouched users hit the cache, and the new user is immediately servable
for rec in svc.serve([PPRQuery("social", u, k=5, precision=26) for u in users]):
    print(f"user {rec.query.vertex:5d}: top-5 recs {rec.vertices.tolist()} "
          f"({rec.precision}, {rec.source})")
newbie = svc.serve([PPRQuery("social", 2000, k=5, precision=26)])[0]
print(f"user  2000: top-5 recs {newbie.vertices.tolist()} "
      f"({newbie.precision}, {newbie.source})")

t = svc.telemetry_summary()
print(f"telemetry: {t['waves']:.0f} waves, early-exit saved "
      f"{t['iterations_saved']:.0f} iterations, warm-start saved "
      f"{t['warm_start_iterations_saved']:.0f} more on "
      f"{t['warm_start_columns']:.0f} re-converged columns")
