"""Quickstart: reduced-precision Personalized PageRank in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small power-law graph, runs batched PPR at the paper's Q1.25
fixed-point format, and compares the top-10 ranking against the float64
oracle — the whole paper in miniature.
"""
import numpy as np

from repro.core import PPRConfig, Q1_25, run_ppr
from repro.core.metrics import full_report, topk_indices
from repro.graphs import holme_kim_powerlaw, ppr_reference

# 1. a social-network-like graph (Holme–Kim powerlaw, paper Table 1)
g = holme_kim_powerlaw(5000, m=8, seed=0)
print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} sparsity={g.sparsity:.1e}")

# 2. personalized PageRank for 4 users at once (κ-batching), 26-bit fixed point
users = np.array([17, 42, 1337, 4242])
scores, deltas = run_ppr(g, users, PPRConfig(iterations=10, kappa=4), fmt=Q1_25)

# 3. compare against the converged float64 CPU oracle
ref = ppr_reference(g, users, iterations=100)
for i, u in enumerate(users):
    rep = full_report(scores[:, i], ref[:, i])
    top = topk_indices(scores[:, i], 5)
    print(f"user {u:5d}: top-5 recs {top.tolist()}  "
          f"NDCG={rep['ndcg']:.4f} edit@10={rep['edit@10']}")
print(f"fixed-point converged to absorbing state: delta trace {deltas[-3:]}")
