"""Quickstart: serve reduced-precision PPR recommendations through the
futures API and absorb live graph updates — the paper's architecture operated
as the recommender service it was built for.

    PYTHONPATH=src python examples/quickstart.py

register → submit (PPRFuture per query) → flush (κ-batched waves, bit-exact
Q1.25 fixed point, top-K) → apply_delta (epoch-versioned edge ingestion,
scoped invalidation, warm-start re-convergence) → submit again.
"""
import numpy as np

from repro.graph_updates import EdgeDelta
from repro.graphs import holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService

# 1. a social-network-like graph (Holme–Kim powerlaw, paper Table 1)
g = holme_kim_powerlaw(2000, m=6, seed=0)
print(f"graph: |V|={g.num_vertices:,} |E|={g.num_edges:,} sparsity={g.sparsity:.1e}")

# 2. a serving instance: κ-batched waves, early-exit at the fixed-point
#    absorbing state (paper Fig. 7), warm-start seeds across graph updates.
#    register_graph picks the "single" engine family (single-device float +
#    bit-exact fixed backends); pass mesh= for the "sharded" family.
svc = PPRService(kappa=4, iterations=40, early_exit=True, warm_start=True)
svc.register_graph("social", g, formats=[26])       # pre-quantize Q1.25

# 3. submit returns a PPRFuture per query; flush() launches the pending waves
#    and resolves every future (a future's own .result() also drives)
users = [17, 42, 1337, 1999]
futures = [svc.submit(PPRQuery("social", u, k=5, precision=26)) for u in users]
svc.flush()
for fut in futures:
    rec = fut.result()
    print(f"user {rec.query.vertex:5d}: top-5 recs {rec.vertices.tolist()} "
          f"({rec.precision}, {rec.source})")

# 4. a follower burst arrives: one new user joins (vertex growth) and follows
#    two existing users, one of whom follows back — absorbed in place, no
#    re-registration: only cache entries near the change are invalidated
delta = EdgeDelta(add_src=[2000, 2000, 17], add_dst=[17, 42, 2000],
                  new_num_vertices=2001)
report = svc.apply_delta("social", delta)
print(f"delta applied in {report['apply_s']*1e3:.1f} ms: epoch {report['epoch']}, "
      f"|V| -> {report['num_vertices']}, cache dropped {report['cache_dropped']} "
      f"/ retained {report['cache_retained']} (frontier {report['frontier_size']})")

# 5. serve the updated graph — invalidated users recompute (warm-started from
#    their pre-delta converged state, so the wave early-exits sooner),
#    untouched users resolve from cache before submit even returns, and the
#    new user is immediately servable; done-callbacks fire on resolution
futures = [svc.submit(PPRQuery("social", u, k=5, precision=26)) for u in users]
futures[0].add_done_callback(
    lambda f: print(f"(callback) user {f.query.vertex} resolved "
                    f"from {f.result().source}"))
svc.flush()
for fut in futures:
    rec = fut.result()
    print(f"user {rec.query.vertex:5d}: top-5 recs {rec.vertices.tolist()} "
          f"({rec.precision}, {rec.source})")
newbie = svc.submit(PPRQuery("social", 2000, k=5, precision=26)).result()
print(f"user  2000: top-5 recs {newbie.vertices.tolist()} "
      f"({newbie.precision}, {newbie.source})")

t = svc.telemetry_summary()
print(f"telemetry: {t['waves']:.0f} waves "
      f"({t.get('engine_fixed_waves', 0):.0f} on the fixed engine), "
      f"early-exit saved {t['iterations_saved']:.0f} iterations, "
      f"warm-start saved {t['warm_start_iterations_saved']:.0f} more on "
      f"{t['warm_start_columns']:.0f} re-converged columns")
