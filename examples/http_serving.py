"""Serving PPR over HTTP: the futures API behind the asyncio tier.

    PYTHONPATH=src python examples/http_serving.py

Starts a `PPRHTTPServer` in-process (ephemeral port), fires a handful of
requests with the bundled asyncio client — explicit precision, auto
precision, a cache hit, a validation error — then pushes a burst past the
admission high-water mark to show load shedding (429 + Retry-After) and
SLO-aware quality degradation kicking in, and prints the /v1/stats audit
trail of every decision plus the flight recorder's reconstruction of the
incident (the shed/degrade/recover timeline and one query's span tree).
"""
import asyncio

from repro.graphs import holme_kim_powerlaw
from repro.obs import format_event, format_trace
from repro.ppr_serving import AdmissionConfig, PPRHTTPServer, PPRService
from repro.ppr_serving.http import AsyncHTTPClient, http_request


async def main():
    # 1. a graph behind a serving instance; tight water marks so the demo
    #    overloads on a laptop (production values scale with κ)
    g = holme_kim_powerlaw(1500, m=4, seed=0)
    svc = PPRService(kappa=4, iterations=10, max_wait=0.002, tracing=True)
    svc.register_graph("social", g, formats=[26])
    server = PPRHTTPServer(svc, admission=AdmissionConfig(
        high_water=10, low_water=2, deepen_water=4, kappa_max=16,
        degrade_water=6, degrade_low_water=2, degraded_target=0.93))
    await server.start()
    host, port = server.host, server.port
    print(f"serving on http://{host}:{port}")

    # 2. ordinary traffic: explicit Q1.25, then auto precision
    for body in ({"graph": "social", "vertex": 17, "k": 5, "precision": 26},
                 {"graph": "social", "vertex": 42, "k": 5,
                  "precision": "auto", "quality_target": 0.95}):
        status, _, rec = await http_request(host, port, "POST", "/v1/ppr", body)
        print(f"vertex {body['vertex']}: HTTP {status} served at "
              f"{rec['precision']} from {rec['source']}, "
              f"top-5 {[r['vertex'] for r in rec['recommendations']]}")

    # 3. the same query again — resolved from the LRU before a wave forms
    status, _, rec = await http_request(
        host, port, "POST", "/v1/ppr",
        {"graph": "social", "vertex": 17, "k": 5, "precision": 26})
    print(f"repeat vertex 17: HTTP {status} from {rec['source']}")

    # 4. a bad request is a clean 400, not a poisoned wave
    status, _, err = await http_request(
        host, port, "POST", "/v1/ppr",
        {"graph": "social", "vertex": 17, "k": 0})
    print(f"k=0: HTTP {status} ({err['error']})")

    # 5. overload: a concurrent burst far past the high-water mark — the
    #    tail sheds with Retry-After, deep-queue auto traffic degrades to
    #    the 0.93 target, and both recover once the queue drains
    clients = [AsyncHTTPClient(host, port) for _ in range(32)]
    results = await asyncio.gather(*[
        c.request("POST", "/v1/ppr",
                  {"graph": "social", "vertex": 100 + i, "k": 5,
                   "precision": "auto", "quality_target": 0.95})
        for i, c in enumerate(clients)])
    for c in clients:
        await c.close()
    statuses = [r[0] for r in results]
    shed = [r for r in results if r[0] == 429]
    degraded = sum(r[2].get("degraded", False) for r in results if r[0] == 200)
    print(f"burst of {len(results)}: {statuses.count(200)} served "
          f"({degraded} at the degraded target), {len(shed)} shed"
          + (f" (Retry-After {shed[0][1]['retry-after']}s)" if shed else ""))

    # 6. the audit trail: every admission decision is telemetry
    status, _, stats = await http_request(host, port, "GET", "/v1/stats")
    print("stats:")
    for key in ("queries_served", "queries_shed", "queue_depth_peak",
                "shed_engaged_events", "shed_recovered_events",
                "slo_degrade_events", "slo_degraded_queries",
                "slo_recover_events", "kappa_deepen_events",
                "kappa_relax_events", "cache_hit_rate"):
        print(f"  {key:24s} {stats[key]}")

    # 7. the flight recorder replays the incident itself: the control-plane
    #    timeline (κ deepened → quality degraded → shedding engaged → queue
    #    drained → recovered) and, for any one query, the spans of what it
    #    waited on and where its wave spent the time
    print("flight recorder — incident timeline:")
    for ev in svc.recorder.events():
        print("  " + format_event(ev))
    burst_query = next(t for t in reversed(svc.recorder.traces())
                       if t["kind"] == "query"
                       and t["root"]["attrs"].get("source") == "wave")
    print("flight recorder — one burst query's span tree:")
    for line in format_trace(burst_query).splitlines():
        print("  " + line)

    await server.stop()
    print("server stopped")


if __name__ == "__main__":
    asyncio.run(main())
