"""A realistic recommender built on the paper's system: e-commerce
co-purchasing recommendations with reduced-precision PPR + the serving-style
request batcher, including the bit-width/latency trade-off the paper studies.

    PYTHONPATH=src python examples/ppr_recommender.py
"""
import time

import numpy as np

from repro.core import PPRConfig, batched_ppr, format_for_bits
from repro.core.metrics import topk_indices
from repro.graphs import holme_kim_powerlaw, ppr_reference

# Amazon-co-purchasing-like graph (paper Table 1: |V|=128k scaled down)
g = holme_kim_powerlaw(12800, m=3, seed=1)
print(f"catalog graph: |V|={g.num_vertices:,} products, |E|={g.num_edges:,} co-purchases")

# 100 user queries (paper §5.1 protocol), κ=8 batching
rng = np.random.default_rng(0)
queries = rng.integers(0, g.num_vertices, 100)

for bits in (20, 26):
    fmt = format_for_bits(bits)
    cfg = PPRConfig(iterations=10, kappa=8)
    batched_ppr(g, queries[:8], cfg, fmt=fmt)   # warm up jit
    t0 = time.time()
    scores = batched_ppr(g, queries, cfg, fmt=fmt)
    dt = time.time() - t0
    print(f"\nQ1.{bits-1}: 100 queries in {dt*1000:.0f} ms "
          f"({100/dt:.0f} queries/s)")
    # quality check on 3 queries vs converged oracle
    ref = ppr_reference(g, queries[:3], iterations=100)
    for i in range(3):
        top_fast = topk_indices(scores[:, i], 10)
        top_true = topk_indices(ref[:, i], 10)
        overlap = len(set(top_fast.tolist()) & set(top_true.tolist()))
        print(f"  query {queries[i]:6d}: top-10 overlap with oracle {overlap}/10 "
              f"top-3 recs {top_fast[:3].tolist()}")
