"""E-commerce co-purchasing recommendations served through `PPRService`'s
futures API: κ-batched admission waves, per-query bit-width, streaming top-K,
and an LRU result cache — the paper's architecture (reduced-precision
streaming SpMV for PPR) operated as the recommender service it was built for.

    PYTHONPATH=src python examples/ppr_recommender.py
"""
import numpy as np

from repro.core.metrics import topk_indices
from repro.graphs import holme_kim_powerlaw, ppr_reference
from repro.ppr_serving import PPRQuery, PPRService

# Amazon-co-purchasing-like graph (paper Table 1: |V|=128k scaled down)
g = holme_kim_powerlaw(12800, m=3, seed=1)
print(f"catalog graph: |V|={g.num_vertices:,} products, |E|={g.num_edges:,} co-purchases")

service = PPRService(kappa=8, iterations=10, cache_capacity=1024)
service.register_graph("amazon", g, formats=[20, 26])  # pre-quantize at registration


# 100 user queries (paper §5.1 protocol), served per bit-width
rng = np.random.default_rng(0)
users = rng.integers(0, g.num_vertices, 100)

for bits in (20, 26):
    # warm up jit on one wave, then measure a fresh service pass (the jitted
    # step/top-k executables are process-global, so only stats start cold)
    service.run_batch([PPRQuery("amazon", int(v), k=10, precision=bits)
                       for v in users[:8]])
    svc = PPRService(kappa=8, iterations=10, cache_capacity=1024)
    svc.register_graph("amazon", g, formats=[bits])
    recs = svc.run_batch([PPRQuery("amazon", int(v), k=10, precision=bits)
                          for v in users])
    s = svc.telemetry_summary()
    print(f"\nQ1.{bits-1}: {s['queries_served']:.0f} queries in "
          f"{sum(svc.telemetry.wave_latencies_s)*1000:.0f} ms "
          f"({s['queries_per_s']:.0f} queries/s, "
          f"{s['waves']:.0f} waves on the {s.get('engine_fixed_waves', 0):.0f}-wave "
          f"fixed engine, occupancy {s['mean_occupancy']:.2f}, "
          f"wave p95 {s['wave_latency_p95_s']*1000:.0f} ms)")

    # quality check on 3 queries vs converged oracle (self excluded, like the service)
    ref = ppr_reference(g, users[:3], iterations=100)
    for i in range(3):
        s_ref = ref[:, i].copy()
        s_ref[users[i]] = -np.inf
        top_true = topk_indices(s_ref, 10)
        top_fast = recs[i].vertices
        overlap = len(set(top_fast.tolist()) & set(top_true.tolist()))
        print(f"  user {users[i]:6d}: top-10 overlap with oracle {overlap}/10 "
              f"top-3 recs {top_fast[:3].tolist()}")

# repeat traffic: the LRU cache short-circuits the whole iteration pipeline —
# a repeat submit returns an already-resolved future (no wave, no flush)
repeat = [PPRQuery("amazon", int(v), k=10, precision=26) for v in users[:20]]
service.run_batch(repeat)
again = [service.submit(q) for q in repeat]
assert all(f.done() for f in again)            # resolved before flush
s = service.telemetry_summary()
print(f"\nrepeat traffic: {sum(f.result().source == 'cache' for f in again)}/20 "
      f"served from cache (service hit rate {s['cache_hit_rate']:.2f})")

# adaptive precision: ask for a quality target instead of a bit-width — the
# autotune subsystem picks the cheapest Q format whose shadow-sampled NDCG
# meets it, and early-exits waves at the fixed-point absorbing state
from repro.autotune import AutotuneConfig, ShadowConfig

auto_svc = PPRService(kappa=8, iterations=100, early_exit=True,
                      autotune=AutotuneConfig(
                          shadow=ShadowConfig(sample_fraction=0.5, seed=0)))
auto_svc.register_graph("amazon", g)
auto_recs = auto_svc.run_batch(
    [PPRQuery("amazon", int(v), k=10, precision="auto", quality_target=0.95)
     for v in users[:32]])
s = auto_svc.telemetry_summary()
served = {r.precision for r in auto_recs}
print(f"\nauto precision (NDCG target 0.95): served at {sorted(served)}, "
      f"shadow NDCG {s['shadow_quality_mean']:.4f} over "
      f"{s['shadow_evaluations']:.0f} samples, early exit saved "
      f"{s['iterations_saved']:.0f} iterations across {s['waves']:.0f} waves")
