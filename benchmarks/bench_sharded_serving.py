"""Sharded-serving benchmark: queries/s vs shard count.

The paper scales by partitioning the edge stream across memory channels; the
Top-K SpMV follow-up (arXiv 2103.04808) shows the same partitioning unlocks
multi-channel/multi-device bandwidth for the serving workload.  This measures
that end-to-end: one graph served by ``PPRService`` registered single-device
(shards=1) and on ``jax.sharding`` meshes of growing width, float32 and
fixed-point, reporting queries/s and wave latency per shard count.

    PYTHONPATH=src python benchmarks/bench_sharded_serving.py [--scale 0.02] [--dry-run]

Per run-book, multi-device work runs in a subprocess with forced host devices
so the invoking process keeps its single default device: ``main`` re-executes
this file with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and
parses the JSON rows the inner run prints.  (On a real multi-chip platform the
forced-host-device flag is unnecessary — the inner run only forces it when the
visible device count is short.)

``--dry-run`` is the CI smoke path (tiny graph, shards 1/2, one precision).
Output is the house ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

_ROW_MARKER = "BENCH_SHARDED_ROWS:"


def _inner(scale: float, n_queries: int, kappa: int, iterations: int,
           shards: Sequence[int], precisions: Sequence[Optional[int]],
           seed: int = 0) -> List[Dict]:
    """Runs with devices available; one PPRService per (shards, precision)."""
    import jax
    import numpy as np

    from repro.graphs import holme_kim_powerlaw
    from repro.ppr_serving import PPRQuery, PPRService

    # deliberately not a multiple of any shard count: the ceil-division padded
    # layout is the production case, so it is the benchmarked one
    n_vertices = max(131, int(128000 * scale)) | 1
    g = holme_kim_powerlaw(n_vertices, m=3, seed=1)
    rng = np.random.default_rng(seed)
    users = rng.integers(0, g.num_vertices, n_queries)
    rows: List[Dict] = []
    for n_shards in shards:
        mesh = None if n_shards == 1 else \
            jax.make_mesh((n_shards,), ("shard",))
        for prec in precisions:
            svc = PPRService(kappa=kappa, iterations=iterations,
                             cache_capacity=0)       # measure compute, not cache
            svc.register_graph("g", g, formats=[p for p in (prec,) if p],
                               mesh=mesh)
            queries = [PPRQuery("g", int(v), k=10, precision=prec)
                       for v in users]
            svc.run_batch(queries[: min(kappa, n_queries)])  # warm up jit
            svc.telemetry.reset()      # count only the timed traffic
            svc.run_batch(queries)
            s = svc.telemetry_summary()
            engine_key = ("float" if prec is None else "fixed") if mesh is None \
                else ("sharded_float" if prec is None else "sharded_fixed")
            rows.append({
                "shards": n_shards,
                "precision": "f32" if prec is None else f"q{prec}",
                "engine": engine_key,
                "V": g.num_vertices,
                "E": g.num_edges,
                "kappa": kappa,
                "queries": n_queries,
                "queries_per_s": s["queries_per_s"],
                "p50_s": s["wave_latency_p50_s"],
                "p95_s": s["wave_latency_p95_s"],
                "engine_mean_s": s.get(f"engine_{engine_key}_latency_mean_s", 0.0),
                "engine_p95_s": s.get(f"engine_{engine_key}_latency_p95_s", 0.0),
                "waves": s["waves"],
            })
    return rows


def run(scale: float = 0.02, n_queries: int = 32, kappa: int = 8,
        iterations: int = 10, shards: Sequence[int] = (1, 2, 4, 8),
        precisions: Sequence[Optional[int]] = (None, 26)) -> List[Dict]:
    """Spawn the inner measurement with enough (forced) host devices."""
    need = max(shards)
    env = dict(os.environ)
    flags = env.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        # append, preserving any operator-set flags (threading, determinism);
        # an operator-forced device count is respected as-is
        env["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={need}").strip()
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    spec = json.dumps({"scale": scale, "n_queries": n_queries, "kappa": kappa,
                       "iterations": iterations, "shards": list(shards),
                       "precisions": list(precisions)})
    out = subprocess.run([sys.executable, os.path.abspath(__file__),
                          "--inner", spec],
                         capture_output=True, text=True, env=env, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"inner sharded bench failed:\n{out.stdout}\n{out.stderr}")
    for line in out.stdout.splitlines():
        if line.startswith(_ROW_MARKER):
            return json.loads(line[len(_ROW_MARKER):])
    raise RuntimeError(f"inner sharded bench produced no rows:\n{out.stdout}")


def main(scale: float = 0.02, dry_run: bool = False) -> List[Dict]:
    if dry_run:
        rows = run(scale=0.005, n_queries=8, kappa=4, shards=(1, 2),
                   precisions=(26,))
    else:
        rows = run(scale=scale)
    print("# sharded_serving: name,us_per_call,derived")
    for r in rows:
        us = 1e6 / r["queries_per_s"] if r["queries_per_s"] else 0.0
        print(f"sharded_s{r['shards']}_{r['precision']},{us:.0f},"
              f"qps={r['queries_per_s']:.1f}"
              f";p50_us={r['p50_s']*1e6:.0f};p95_us={r['p95_s']*1e6:.0f}"
              f";V={r['V']};waves={r['waves']}"
              f";engine={r['engine']}"
              f";engine_p95_us={r['engine_p95_s']*1e6:.0f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny graph, shards 1/2 — the CI smoke path")
    ap.add_argument("--inner", metavar="JSON_SPEC", default=None,
                    help=argparse.SUPPRESS)   # subprocess protocol, not a user flag
    args = ap.parse_args()
    if args.inner is not None:
        spec = json.loads(args.inner)
        spec["precisions"] = [None if p is None else int(p)
                              for p in spec["precisions"]]
        print(_ROW_MARKER + json.dumps(_inner(**spec)))
    else:
        main(scale=args.scale, dry_run=args.dry_run)
