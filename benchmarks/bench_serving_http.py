"""Latency-under-load bench for the HTTP serving tier (BENCH_serving_http).

A closed-loop or open-loop traffic generator drives the real asyncio server
over real sockets at ≥2 offered-load levels and records the control plane's
response: admitted-request latency (p50/p95), shed rate past the high-water
mark, SLO quality degradation under sustained overload, and the recovery
transitions once load drops — the serving analogue of the paper's
throughput-vs-precision tables, with the precision dial turned *by load*.
Each level also runs the burn-rate monitor (bench-scale windows) and
reports latency-SLO compliance plus how many burn alerts engaged.

    PYTHONPATH=src python benchmarks/bench_serving_http.py [--scale 0.02] [--dry-run]

Arrival modes:
  closed  N concurrent "users", each issuing its next request only after the
          previous response — offered load self-limits to service capacity,
          so this is the un-shed baseline row.
  open    requests fired at a target rate regardless of completions (the
          "millions of users" shape) — offered load exceeds capacity, the
          queue builds, and the shed/degrade/deepen escalation engages.

Output is the house ``name,us_per_call,derived`` CSV (us_per_call = mean
per-admitted-request wall time).
"""
from __future__ import annotations

import argparse
import asyncio
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.graphs import holme_kim_powerlaw
from repro.obs import SLOSpec
from repro.ppr_serving import (AdmissionConfig, PPRHTTPServer, PPRQuery,
                               PPRService)
from repro.ppr_serving.http import AsyncHTTPClient, http_request

#: (mode, offered) levels — closed: concurrent users; open: requests/s
LEVELS: Tuple[Tuple[str, int], ...] = (("closed", 4), ("open", 100),
                                       ("open", 400))


def _slo_specs() -> Tuple[SLOSpec, ...]:
    """Latency + shed SLOs on bench-scale burn windows: production uses the
    SRE 5m/1h/6h pairs, but a level here lasts seconds, so the windows
    shrink with it — same algebra, faster clock."""
    windows = {"fast_windows": (0.5, 2.0), "slow_windows": (2.0, 8.0)}
    return (SLOSpec(name="latency_p95", kind="latency",
                    objective=0.262144, budget=0.05, **windows),
            SLOSpec(name="shed_rate", kind="shed", budget=0.05, **windows))


def _admission(kappa: int) -> AdmissionConfig:
    """Water marks in waves'-worth of queries, scaled from κ so the same
    escalation story holds at any batch depth."""
    return AdmissionConfig(
        high_water=3 * kappa, low_water=kappa // 2 or 1,
        deepen_water=kappa, kappa_max=4 * kappa,
        degrade_water=2 * kappa, degrade_low_water=kappa // 2 or 1,
        degraded_target=0.93, retry_after_s=0.05)


async def _drain(host: str, port: int, timeout_s: float = 30.0) -> bool:
    """Poll /v1/healthz until the queue is empty and shed/degrade have
    recovered — the 'load drops' half of the SLO story."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < timeout_s:
        _, _, h = await http_request(host, port, "GET", "/v1/healthz")
        if h["queue_depth"] == 0 and not h["shedding"] and not h["degrading"]:
            return True
        await asyncio.sleep(0.02)
    return False


async def _run_level(g, mode: str, offered: int, n_requests: int,
                     kappa: int, iterations: int, seed: int) -> Dict:
    svc = PPRService(kappa=kappa, iterations=iterations, max_wait=0.002,
                     cache_capacity=0,          # measure compute, not cache
                     slo=_slo_specs())
    svc.register_graph("g", g, formats=[26])
    # warm the jit caches outside the timed window (base κ; deepened κ
    # shapes compile mid-overload, which the open-loop rows absorb as real
    # first-hit cost)
    # repro: allow[ASY303] jit warmup before server.start() — nothing else is scheduled on the loop yet
    svc.run_batch([PPRQuery("g", v, k=10, precision="auto")
                   for v in range(min(kappa, g.num_vertices))])
    svc.telemetry.reset()
    server = PPRHTTPServer(svc, admission=_admission(kappa),
                           pump_interval_s=0.002)
    await server.start()
    host, port = server.host, server.port

    rng = np.random.default_rng(seed)
    vertices = rng.integers(0, g.num_vertices, n_requests)
    latencies: List[float] = []        # admitted (HTTP 200) only
    statuses: List[int] = []
    degraded_served = 0

    def _body(v) -> Dict:
        return {"graph": "g", "vertex": int(v), "k": 10,
                "precision": "auto", "quality_target": 0.95}

    async def _one(client: Optional[AsyncHTTPClient], v) -> None:
        nonlocal degraded_served
        t0 = time.perf_counter()
        if client is not None:
            status, _, payload = await client.request("POST", "/v1/ppr",
                                                      _body(v))
        else:
            status, _, payload = await http_request(host, port, "POST",
                                                    "/v1/ppr", _body(v))
        statuses.append(status)
        if status == 200:
            latencies.append(time.perf_counter() - t0)
            degraded_served += bool(payload.get("degraded"))

    t_start = time.perf_counter()
    if mode == "closed":
        clients = [AsyncHTTPClient(host, port) for _ in range(offered)]
        chunks = np.array_split(vertices, offered)

        async def _user(client, verts):
            for v in verts:
                await _one(client, v)

        await asyncio.gather(*[_user(c, ch)
                               for c, ch in zip(clients, chunks)])
        for c in clients:
            await c.close()
    elif mode == "open":
        interval = 1.0 / offered

        async def _arrival(i, v):
            await asyncio.sleep(i * interval)
            await _one(None, v)

        await asyncio.gather(*[_arrival(i, v)
                               for i, v in enumerate(vertices)])
    else:
        raise ValueError(f"unknown arrival mode {mode!r}")
    elapsed = time.perf_counter() - t_start

    recovered = await _drain(host, port)
    _, _, stats = await http_request(host, port, "GET", "/v1/stats")
    await server.stop()

    # SLO accounting for the row: in-objective fraction of admitted-query
    # latency, and how many times a burn alert engaged during the level
    slo = {s["name"]: s for s in svc.slo.status()["specs"]}
    lat_spec = slo["latency_p95"]
    lat_events = lat_spec["good_total"] + lat_spec["bad_total"]
    slo_compliance = (lat_spec["good_total"] / lat_events
                      if lat_events else 1.0)
    slo_burn_events = len(svc.recorder.events_of_kind("slo_burning"))

    lat = np.asarray(latencies, np.float64)
    ok = int(lat.size)
    return {
        "mode": mode,
        "offered": offered,            # users (closed) or req/s (open)
        "requests": n_requests,
        "admitted": ok,
        "shed": statuses.count(429),
        "elapsed_s": elapsed,
        "admitted_per_s": ok / elapsed if elapsed else 0.0,
        "latency_p50_ms": float(np.percentile(lat, 50) * 1e3) if ok else 0.0,
        "latency_p95_ms": float(np.percentile(lat, 95) * 1e3) if ok else 0.0,
        "degraded_served": int(degraded_served),
        "recovered": bool(recovered),
        "queue_depth_peak": stats["queue_depth_peak"],
        "queries_shed": stats["queries_shed"],
        "shed_engaged_events": stats["shed_engaged_events"],
        "shed_recovered_events": stats["shed_recovered_events"],
        "slo_degrade_events": stats["slo_degrade_events"],
        "slo_degraded_queries": stats["slo_degraded_queries"],
        "slo_recover_events": stats["slo_recover_events"],
        "slo_compliance": float(slo_compliance),
        "slo_burn_events": int(slo_burn_events),
        "queries_deadline_shed": stats["queries_deadline_shed"],
        "kappa_deepen_events": stats["kappa_deepen_events"],
        "kappa_relax_events": stats["kappa_relax_events"],
        "V": g.num_vertices,
        "E": g.num_edges,
    }


def run(scale: float = 0.02, n_requests: int = 128, kappa: int = 4,
        iterations: int = 10, levels=LEVELS, seed: int = 0) -> List[Dict]:
    g = holme_kim_powerlaw(max(128, int(128000 * scale)), m=3, seed=1)
    rows = []
    for mode, offered in levels:
        rows.append(asyncio.run(_run_level(
            g, mode, offered, n_requests, kappa, iterations, seed)))
    return rows


def main(scale: float = 0.02, dry_run: bool = False):
    if dry_run:
        # one un-shed closed row + one overload open row: the minimum that
        # still demonstrates shed-above-high-water AND degrade/recover
        rows = run(scale=0.005, n_requests=48, kappa=2, iterations=4,
                   levels=(("closed", 2), ("open", 400)))
    else:
        rows = run(scale=scale)
    print("# serving_http: name,us_per_call,derived")
    for r in rows:
        us = (1e6 * r["elapsed_s"] / r["admitted"]) if r["admitted"] else 0.0
        print(f"http_{r['mode']}{r['offered']},{us:.0f},"
              f"admitted={r['admitted']}/{r['requests']}"
              f";shed={r['shed']}"
              f";p50_ms={r['latency_p50_ms']:.1f}"
              f";p95_ms={r['latency_p95_ms']:.1f}"
              f";degraded={r['degraded_served']}"
              f";recovered={int(r['recovered'])}"
              f";depth_peak={r['queue_depth_peak']}"
              f";slo_compliance={r['slo_compliance']:.3f}"
              f";slo_burns={r['slo_burn_events']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny graph, two load levels — the CI smoke path")
    args = ap.parse_args()
    main(scale=args.scale, dry_run=args.dry_run)
