"""Adaptive-precision sweep: quality-targeted autotuning vs static formats.

For each quality target the service serves ``precision="auto"`` traffic through
the closed loop (repro.autotune): the controller picks the Q format, waves
early-exit at the fixed-point absorbing state/cycle (paper Fig. 7), and the
shadow estimator reports the NDCG actually achieved against the float32
reference.  Static rows serve the same traffic at the paper's fixed formats
with the fixed 10-iteration baseline budget (the repo's pre-autotune
behaviour) for comparison.

Reported per row: achieved NDCG (shadow estimate), mean iterations per wave,
early-exit iterations saved vs running the full budget, and queries/s.

    PYTHONPATH=src python benchmarks/bench_autotune.py [--scale 0.02] [--dry-run]

``--dry-run`` runs one tiny graph / one target in seconds — the CI smoke path
(scripts/ci.sh).  Output is the house ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from repro.autotune import AutotuneConfig, ShadowConfig
from repro.core import PPRConfig, format_for_bits, run_ppr
from repro.core.metrics import ndcg, ranking
from repro.graphs import holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService

BASELINE_ITERATIONS = 10          # paper §5.1: the fixed budget the repo used
STATIC_PRECISIONS = (None, 26, 20)
TARGETS = (0.90, 0.95, 0.99)


def _precision_label(p) -> str:
    return "f32" if p is None else f"q{p}"


def _offline_ndcg(g, prec, vertices, iterations) -> float:
    """Mean full-vector NDCG vs the float32 reference for a few vertices."""
    pers = np.asarray(vertices)
    ref, _ = run_ppr(g, pers, PPRConfig(iterations=iterations))
    if prec is None:
        return 1.0
    got, _ = run_ppr(g, pers, PPRConfig(iterations=iterations),
                     fmt=format_for_bits(prec))
    scores = []
    for i in range(len(pers)):
        r = ref[:, i]
        scores.append(ndcg(got[:, i], r, 50, ref_order=ranking(r)))
    return float(np.mean(scores))


def run(scale: float = 0.02, n_queries: int = 48, kappa: int = 8,
        budget: int = 120, targets=TARGETS, ladder=(20, 22, 24, 26),
        sample_fraction: float = 0.5, seed: int = 0) -> List[Dict]:
    g = holme_kim_powerlaw(max(128, int(128000 * scale)), m=3, seed=1)
    rng = np.random.default_rng(seed)
    users = rng.integers(0, g.num_vertices, n_queries)
    eval_verts = users[:4]
    rows: List[Dict] = []

    # -- static formats at the fixed 10-iteration baseline (pre-autotune repo)
    for prec in STATIC_PRECISIONS:
        svc = PPRService(kappa=kappa, iterations=BASELINE_ITERATIONS,
                         cache_capacity=0)
        svc.register_graph("g", g, formats=[p for p in (prec,) if p])
        svc.run_batch([PPRQuery("g", int(v), k=10, precision=prec)
                       for v in users])
        s = svc.telemetry_summary()
        rows.append({
            "mode": "static", "precision": _precision_label(prec),
            "target": None, "V": g.num_vertices, "E": g.num_edges,
            "achieved_ndcg": _offline_ndcg(g, prec, eval_verts,
                                           BASELINE_ITERATIONS),
            "mean_wave_iters": float(BASELINE_ITERATIONS),
            "iterations_saved": 0, "budget": BASELINE_ITERATIONS,
            "queries_per_s": s["queries_per_s"],
            "shadow_evaluations": 0,
        })

    # -- adaptive precision: quality-target sweep with early exit
    for target in targets:
        cfg = AutotuneConfig(
            ladder=tuple(ladder),
            shadow=ShadowConfig(sample_fraction=sample_fraction,
                                min_samples=2, window=16, seed=seed))
        svc = PPRService(kappa=kappa, iterations=budget, early_exit=True,
                         autotune=cfg, cache_capacity=0)
        svc.register_graph("g", g)
        svc.run_batch([PPRQuery("g", int(v), k=10, precision="auto",
                                quality_target=target) for v in users])
        s = svc.telemetry_summary()
        waves = max(1, int(s["waves"]))
        served = {k[len("served_"):]: v for k, v in s.items()
                  if k.startswith("served_")}
        rows.append({
            "mode": "auto", "precision": "+".join(sorted(served)),
            "target": target, "V": g.num_vertices, "E": g.num_edges,
            "achieved_ndcg": s["shadow_quality_mean"],
            "mean_wave_iters": float(budget) - s["iterations_saved"] / waves,
            "iterations_saved": int(s["iterations_saved"]),
            "budget": budget,
            "queries_per_s": s["queries_per_s"],
            "shadow_evaluations": int(s["shadow_evaluations"]),
            "served": served,
        })
    return rows


def main(scale: float = 0.02, dry_run: bool = False):
    if dry_run:
        rows = run(scale=0.005, n_queries=8, kappa=4, budget=80,
                   targets=(0.95,), ladder=(16, 20), sample_fraction=1.0)
    else:
        rows = run(scale=scale)
    print("# autotune: name,us_per_call,derived")
    for r in rows:
        name = f"autotune_{r['mode']}" + \
            (f"_t{r['target']}" if r["target"] is not None
             else f"_{r['precision']}")
        us = 1e6 / r["queries_per_s"] if r["queries_per_s"] else 0.0
        print(f"{name},{us:.0f},"
              f"ndcg={r['achieved_ndcg']:.5f};"
              f"wave_iters={r['mean_wave_iters']:.1f};"
              f"saved_vs_budget{r['budget']}={r['iterations_saved']};"
              f"baseline_iters={BASELINE_ITERATIONS};"
              f"qps={r['queries_per_s']:.1f};"
              f"served={r['precision']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny graph, one target — the CI smoke path")
    args = ap.parse_args()
    main(scale=args.scale, dry_run=args.dry_run)
