"""Table 2 / §5.1 analogue: SpMV kernel characterization.

Reports (a) measured CPU wall-time of the production jnp path (XLA scatter-add)
per bit-width, (b) the Pallas kernel's roofline-model TPU time derived from its
block structure (edge packets + P-tile traffic), and (c) padding overhead of
the 2-D blocking — the quantities that replace FPGA LUT/DSP/clock columns on
a TPU (DESIGN.md §2).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Q1_25, format_for_bits, spmv_fixed, spmv_float
from repro.core.coo import BlockedCOO
from repro.graphs import paper_graph_suite
from repro.roofline.analysis import HBM_BW


def _time(f, repeat=3):
    f()  # warm
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        best = min(best, time.perf_counter() - t0)
    return best


def kernel_roofline_time(blocked: BlockedCOO, kappa: int, bits: int) -> Dict[str, float]:
    """HBM bytes of the Pallas schedule: edge stream + P-tile loads + out tiles.

    Uses the packed 16-bit block-local indices when v_tile ≤ 65536 (the
    beyond-paper compression the 2-D blocking enables)."""
    e_pad = blocked.num_packets * blocked.packet
    edge_bytes = blocked.edge_stream_bytes(value_bits=bits)
    # every (dst,src) block with ≥1 packet loads a v_tile×κ P slice once
    starts = blocked.block_starts
    nonempty = int(((starts[1:] - starts[:-1]) > 0).sum())
    p_bytes = nonempty * blocked.v_tile * kappa * bits / 8.0
    out_bytes = blocked.n_dst * blocked.v_tile * kappa * bits / 8.0
    total = edge_bytes + p_bytes + out_bytes
    return {"hbm_bytes": total, "tpu_s": total / HBM_BW,
            "pad_overhead": blocked.pad_overhead, "nonempty_blocks": nonempty}


def run(scale: float = 0.02, kappa: int = 8) -> List[Dict]:
    suite = paper_graph_suite(scale=scale)
    rng = np.random.default_rng(0)
    rows = []
    for name in ["gnp_1e5", "pl_2e5", "twitter_like"]:
        g = suite[name]
        v = g.num_vertices
        p = jnp.asarray((rng.random((v, kappa)) / v).astype(np.float32))
        x, y = jnp.asarray(g.x), jnp.asarray(g.y)
        val = jnp.asarray(g.val)
        f32 = jax.jit(lambda x, y, val, p: spmv_float(x, y, val, p, v))
        t_f32 = _time(lambda: f32(x, y, val, p))
        fmt = Q1_25
        praw = jnp.asarray((np.asarray(p) * fmt.scale).astype(np.uint32))
        vraw = jnp.asarray(g.quantized_val(fmt))
        fq = jax.jit(lambda x, y, vr, pr: spmv_fixed(x, y, vr, pr, v, fmt))
        t_q = _time(lambda: fq(x, y, vraw, praw))
        blocked = BlockedCOO.build(g, v_tile=4096, packet=256)
        rl26 = kernel_roofline_time(blocked, kappa, 26)
        rl32 = kernel_roofline_time(blocked, kappa, 32)
        rows.append({
            "graph": name, "V": v, "E": g.num_edges,
            "jnp_f32_s": t_f32, "jnp_q26_s": t_q,
            "pallas_tpu_q26_s": rl26["tpu_s"], "pallas_tpu_f32_s": rl32["tpu_s"],
            "bandwidth_gain_26_vs_32": rl32["tpu_s"] / rl26["tpu_s"],
            "pad_overhead": rl26["pad_overhead"],
        })
    return rows


def main(scale=0.02):
    rows = run(scale=scale)
    format_argument(scale=scale)
    print("# Table2/kernel: name,us_per_call,derived")
    for r in rows:
        print(f"spmv_{r['graph']},{r['jnp_f32_s']*1e6:.0f},"
              f"q26_us={r['jnp_q26_s']*1e6:.0f};"
              f"tpu_roofline_q26_us={r['pallas_tpu_q26_s']*1e6:.1f};"
              f"bw_gain_26v32={r['bandwidth_gain_26_vs_32']:.2f};"
              f"pad_overhead={r['pad_overhead']:.2f}")
    return rows


if __name__ == "__main__":
    main()


def format_argument(scale: float = 0.02):
    """Paper §3 COO-vs-CSR streaming argument, quantified (see core/csr_compare)."""
    from repro.core.csr_compare import format_comparison
    from repro.graphs import paper_graph_suite

    suite = paper_graph_suite(scale=scale)
    print("# §3 format argument: name,us_per_call,derived")
    for name in ["gnp_1e5", "ws_1e5", "pl_1e5", "twitter_like"]:
        c = format_comparison(suite[name])
        print(f"format_{name},0,"
              f"coo_util={c['coo_utilization']:.3f};"
              f"csr_gang_util={c['csr_gang_utilization']:.3f};"
              f"csr_sorted_util={c['csr_sorted_utilization']:.3f}")
