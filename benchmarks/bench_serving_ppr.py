"""Serving benchmark: queries/s and wave-latency p50/p95 vs κ and precision.

The paper measures raw PPR execution time (Fig. 3); this measures the same
datapath operated as a query service — κ-batch amortization shows up directly
as queries/s scaling with κ, and reduced precision as lower per-wave latency
(the edge-stream byte model of benchmarks/bench_ppr.py).  Each (κ, precision)
point runs once per engine family — "single" (composed jax-ops SpMV) and
"pallas" (one fused kernel launch per iteration) — so the composed-vs-fused
gap is a committed row pair in BENCH_serving_ppr.json.

    PYTHONPATH=src python benchmarks/bench_serving_ppr.py [--scale 0.02] [--dry-run]

``--dry-run`` runs one tiny graph / two configurations in seconds — the CI
smoke path (scripts/ci.sh).  Output is the house ``name,us_per_call,derived``
CSV (us_per_call = mean per-query service time).
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from repro.graphs import holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService
from repro.ppr_serving.telemetry import WAVE_STAGES

KAPPAS = (1, 4, 8, 16)
PRECISIONS = (None, 26, 20)          # f32 reference + paper's widest/narrowest
ENGINE_FAMILIES = ("single", "pallas")   # composed jax-ops vs fused launch


def _precision_label(p) -> str:
    return "f32" if p is None else f"q{p}"


def _engine_key(family: str, prec) -> str:
    base = "float" if prec is None else "fixed"
    return base if family == "single" else f"{family}_{base}"


def run(scale: float = 0.02, n_queries: int = 64, iterations: int = 10,
        kappas=KAPPAS, precisions=PRECISIONS, engines=ENGINE_FAMILIES,
        seed: int = 0) -> List[Dict]:
    g = holme_kim_powerlaw(max(128, int(128000 * scale)), m=3, seed=1)
    rng = np.random.default_rng(seed)
    users = rng.integers(0, g.num_vertices, n_queries)
    rows: List[Dict] = []
    for kappa in kappas:
        for prec in precisions:
            for family in engines:
                rows.append(_run_point(g, kappa, prec, family, users,
                                       n_queries, iterations))
    return rows


def _run_point(g, kappa: int, prec, family: str, users, n_queries: int,
               iterations: int) -> Dict:
    formats = [p for p in (prec,) if p]
    svc = PPRService(kappa=kappa, iterations=iterations,
                     cache_capacity=0)      # measure compute, not cache
    svc.register_graph("g", g, formats=formats, engine=family)
    queries = [PPRQuery("g", int(v), k=10, precision=prec) for v in users]
    svc.run_batch(queries[: min(kappa, n_queries)])   # warm up jit
    svc = PPRService(kappa=kappa, iterations=iterations, cache_capacity=0)
    svc.register_graph("g", g, formats=formats, engine=family)
    svc.run_batch(queries)
    s = svc.telemetry_summary()
    engine_key = _engine_key(family, prec)
    return {
        "kappa": kappa,
        "precision": _precision_label(prec),
        "family": family,
        "engine": engine_key,
        "V": g.num_vertices,
        "E": g.num_edges,
        "queries": n_queries,
        "queries_per_s": s["queries_per_s"],
        "p50_s": s["wave_latency_p50_s"],
        "p95_s": s["wave_latency_p95_s"],
        "engine_mean_s": s.get(f"engine_{engine_key}_latency_mean_s", 0.0),
        "engine_p95_s": s.get(f"engine_{engine_key}_latency_p95_s", 0.0),
        "occupancy": s["mean_occupancy"],
        # per-stage wave timing (obs registry): where the wave's
        # latency went — plan/warm_start/iterate/topk/resolve
        **{f"stage_{stage}_mean_s": s.get(f"stage_{stage}_mean_s", 0.0)
           for stage in WAVE_STAGES},
    }


def main(scale: float = 0.02, dry_run: bool = False):
    if dry_run:
        rows = run(scale=0.005, n_queries=8, kappas=(2, 4), precisions=(None, 20))
    else:
        rows = run(scale=scale)
    print("# serving: name,us_per_call,derived")
    for r in rows:
        us_per_query = 1e6 / r["queries_per_s"] if r["queries_per_s"] else 0.0
        print(f"serving_k{r['kappa']}_{r['precision']}_{r['family']},"
              f"{us_per_query:.0f},"
              f"qps={r['queries_per_s']:.1f}"
              f";p50_us={r['p50_s']*1e6:.0f};p95_us={r['p95_s']*1e6:.0f}"
              f";occupancy={r['occupancy']:.2f}"
              f";engine={r['engine']}"
              f";engine_p95_us={r['engine_p95_s']*1e6:.0f}"
              f";plan_us={r['stage_plan_mean_s']*1e6:.0f}"
              f";iterate_us={r['stage_iterate_mean_s']*1e6:.0f}"
              f";topk_us={r['stage_topk_mean_s']*1e6:.0f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny graph, two configs — the CI smoke path")
    args = ap.parse_args()
    main(scale=args.scale, dry_run=args.dry_run)
