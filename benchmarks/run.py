"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.02] [--full]

Prints ``name,us_per_call,derived`` CSV per row.  --full uses the paper's
graph sizes (|V| = 1e5/2e5, |E| ≈ 1e6/2e6 — minutes on CPU); default scale
runs in ~2 minutes.
"""
from __future__ import annotations

import argparse

from benchmarks import (bench_accuracy, bench_convergence, bench_ppr,
                        bench_serving_ppr, bench_spmv)
from benchmarks import roofline_report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--full", action="store_true", help="paper-size graphs")
    args = ap.parse_args()
    scale = 1.0 if args.full else args.scale

    print("## bench_ppr (paper Fig. 3: speedup vs bit-width x 8 graphs)")
    bench_ppr.main(scale=scale)
    print("\n## bench_accuracy (paper Figs. 4/5/6: accuracy vs bit-width)")
    bench_accuracy.main(scale=scale)
    print("\n## bench_convergence (paper Fig. 7: fixed vs float convergence)")
    bench_convergence.main(scale=scale)
    print("\n## bench_spmv (paper Table 2 analogue: kernel characterization)")
    bench_spmv.main(scale=scale)
    print("\n## bench_serving_ppr (PPRService: queries/s, p50/p95 vs kappa x precision)")
    bench_serving_ppr.main(scale=scale)
    print("\n## roofline (dry-run artifacts; EXPERIMENTS.md section Roofline)")
    roofline_report.main()


if __name__ == "__main__":
    main()
