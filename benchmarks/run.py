"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--scale 0.02] [--full] [--json DIR]

Prints ``name,us_per_call,derived`` CSV per row.  --full uses the paper's
graph sizes (|V| = 1e5/2e5, |E| ≈ 1e6/2e6 — minutes on CPU); default scale
runs in ~2 minutes.

``--json DIR`` additionally writes one machine-readable ``BENCH_<section>.json``
per section ({"bench", "scale", "rows": [...]}) so the perf trajectory can be
tracked across commits without re-parsing the human CSV.

``--check`` (with ``--json``) verifies the baselines after the sweep: every
section that ran must have written a parseable, non-empty file, and a section
that was *skipped* must not leave a baseline behind — a silently-skipped
section would otherwise keep a stale committed baseline looking current.
Exits non-zero on any violation (the CI gate in scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any

import numpy as np

from benchmarks import (bench_accuracy, bench_autotune, bench_convergence,
                        bench_graph_updates, bench_ppr, bench_serving_http,
                        bench_serving_ppr, bench_sharded_serving, bench_spmv)
from benchmarks import roofline_report


def _jsonable(o: Any):
    """JSON encoder default for the numpy scalars/arrays bench rows carry."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def _dump(json_dir: str, section: str, scale: float, rows) -> None:
    path = os.path.join(json_dir, f"BENCH_{section}.json")
    with open(path, "w") as f:
        json.dump({"bench": section, "scale": scale, "rows": rows or []},
                  f, indent=1, default=_jsonable)
    print(f"[json] wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--full", action="store_true", help="paper-size graphs")
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny graphs, reduced configs, every section "
                         "— with --json this produces the BENCH_<section>.json "
                         "baselines the perf trajectory is tracked against")
    ap.add_argument("--json", metavar="DIR", nargs="?", const=".", default=None,
                    help="also write BENCH_<section>.json rows into DIR")
    ap.add_argument("--check", action="store_true",
                    help="after the sweep, fail unless every ran section wrote "
                         "a parseable non-empty BENCH_<section>.json and no "
                         "skipped section left a stale baseline (needs --json)")
    args = ap.parse_args()
    if args.check and not args.json:
        ap.error("--check requires --json (it verifies the written baselines)")
    scale = 1.0 if args.full else args.scale
    if args.dry_run:
        # sections without a native dry-run mode shrink through scale alone
        scale = min(scale, 0.005)
    dry = args.dry_run
    if args.json:
        os.makedirs(args.json, exist_ok=True)

    sections = [
        ("ppr", "bench_ppr (paper Fig. 3: speedup vs bit-width x 8 graphs)",
         lambda: bench_ppr.main(scale=scale)),
        ("accuracy", "bench_accuracy (paper Figs. 4/5/6: accuracy vs bit-width)",
         lambda: bench_accuracy.main(scale=scale)),
        ("convergence", "bench_convergence (paper Fig. 7: fixed vs float convergence)",
         lambda: bench_convergence.main(scale=scale)),
        ("spmv", "bench_spmv (paper Table 2 analogue: kernel characterization)",
         lambda: bench_spmv.main(scale=scale)),
        ("serving_ppr", "bench_serving_ppr (PPRService: queries/s, p50/p95 vs kappa x precision)",
         lambda: bench_serving_ppr.main(scale=scale, dry_run=dry)),
        ("autotune", "bench_autotune (adaptive precision: quality targets vs static formats)",
         lambda: bench_autotune.main(scale=scale, dry_run=dry)),
        ("sharded_serving", "bench_sharded_serving (mesh serving: queries/s vs shard count)",
         lambda: bench_sharded_serving.main(scale=scale, dry_run=dry)),
        ("graph_updates", "bench_graph_updates (delta apply latency, warm vs cold iterations, scoped invalidation)",
         lambda: bench_graph_updates.main(scale=scale, dry_run=dry)),
        ("serving_http", "bench_serving_http (HTTP tier: latency under load, shed/degrade/recover)",
         lambda: bench_serving_http.main(scale=scale, dry_run=dry)),
        ("roofline", "roofline (dry-run artifacts; EXPERIMENTS.md section Roofline)",
         lambda: roofline_report.main()),
    ]
    ran, no_baseline = [], []
    for i, (section, title, fn) in enumerate(sections):
        print(("\n" if i else "") + f"## {title}")
        try:
            rows = fn()
        except FileNotFoundError as e:
            # roofline reads pre-generated experiments/roofline artifacts;
            # their absence must not sink the rest of a --json run
            print(f"[skip] {section}: {e}")
            no_baseline.append(section)
            continue
        if rows is None:
            # report-only section (prints, returns no row schema): it has no
            # baseline to write or verify
            no_baseline.append(section)
            continue
        ran.append(section)
        if args.json:
            _dump(args.json, section, scale, rows)
    if args.check:
        _check_baselines(args.json, ran, no_baseline)


def _check_baselines(json_dir: str, ran, no_baseline) -> None:
    """CI gate: the sweep's baselines must be fresh, parseable, non-empty —
    and a section that produced no rows this sweep (skipped, or report-only)
    must not leave a stale baseline committed."""
    problems = []
    for section in ran:
        path = os.path.join(json_dir, f"BENCH_{section}.json")
        if not os.path.exists(path):
            problems.append(f"{section}: ran but wrote no baseline ({path})")
            continue
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            problems.append(f"{section}: baseline unreadable ({e})")
            continue
        if not doc.get("rows"):
            problems.append(f"{section}: baseline has no rows ({path})")
    for section in no_baseline:
        path = os.path.join(json_dir, f"BENCH_{section}.json")
        if os.path.exists(path):
            problems.append(
                f"{section}: produced no rows this sweep but a baseline "
                f"exists — stale, delete {path} or unbreak the section")
    if problems:
        print("[check] FAILED:")
        for p in problems:
            print(f"  - {p}")
        sys.exit(1)
    print(f"[check] {len(ran)} baselines OK"
          + (f" ({len(no_baseline)} sections without baselines)"
             if no_baseline else ""))


if __name__ == "__main__":
    main()
