"""§Roofline report.

Primary source: the *structured* (trip-count-correct) artifacts in
``experiments/roofline/<variant>/`` (see repro.roofline.structured for why the
naive compiled-graph numbers under-count scan bodies).  The naive per-cell
dry-run artifacts in ``experiments/dryrun/<mesh>/`` are listed afterwards for
the multi-pod compile proof and memory analysis.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ROOF_DIR = "experiments/roofline"
DRYRUN_DIR = "experiments/dryrun"


def load(d: str) -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            rows.append(json.load(f))
    return rows


def _dom(r):
    return max(r["compute_s"], r["memory_s"], r["collective_s"])


def main(base: str = ROOF_DIR):
    for variant in ("baseline", "final"):
        rows = load(os.path.join(base, variant))
        if not rows:
            continue
        rows.sort(key=lambda r: (r["shape"], -_dom(r)))
        print(f"\n== structured roofline [{variant}] ({len(rows)} cells, "
              f"single-pod 16x16) ==")
        print(f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
              f"{'coll_s':>10s} {'bottleneck':10s} {'useful':>7s}")
        for r in rows:
            print(f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.3e} "
                  f"{r['memory_s']:10.3e} {r['collective_s']:10.3e} "
                  f"{r['bottleneck']:10s} {r['useful_flops_ratio']:7.3f}")
        for r in rows:
            print(f"roofline_{variant}_{r['arch']}_{r['shape']},{_dom(r)*1e6:.1f},"
                  f"bottleneck={r['bottleneck']};useful={r['useful_flops_ratio']:.3f}")

    # §Perf variants for the three hillclimbed pairs
    pairs = [("mixtral-8x7b", "train_4k"),
             ("gemma2-27b", "decode_32k"),
             ("moonshot-v1-16b-a3b", "decode_32k")]
    print("\n== §Perf hillclimb variants ==")
    for variant in sorted(os.listdir(base)):
        for arch, shape in pairs:
            fn = os.path.join(base, variant, f"{arch}__{shape}.json")
            if os.path.exists(fn):
                r = json.load(open(fn))
                print(f"perf_{variant}_{arch}_{shape},{_dom(r)*1e6:.1f},"
                      f"compute={r['compute_s']:.3e};memory={r['memory_s']:.3e};"
                      f"coll={r['collective_s']:.3e}")

    # multi-pod compile proof (naive per-cell artifacts)
    for mesh in ("single_pod_16x16", "multi_pod_2x16x16"):
        rows = load(os.path.join(DRYRUN_DIR, mesh))
        if rows:
            print(f"\ndryrun_{mesh}: {len(rows)} cells compiled "
                  f"(memory/cost artifacts in {DRYRUN_DIR}/{mesh}/)")


if __name__ == "__main__":
    main()
