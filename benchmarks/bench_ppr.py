"""Fig. 3 analogue: PPR execution time across bit-widths × 8 graphs.

Measured on this container's CPU (clearly labeled) for *relative* comparisons:
fixed-point Qm.f vs the F32 reference implementation vs the scipy float64 CPU
baseline — the paper's three columns.  The projected-TPU column applies the
roofline byte model (edge stream ∝ bit-width; SpMV is memory-bound), which is
the mechanism behind the paper's FPGA clock-rate speedups.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import PPRConfig, format_for_bits, run_ppr
from repro.graphs import paper_graph_suite, ppr_reference

BITS = [20, 22, 24, 26]


def _time(f, repeat=3):
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        f()
        best = min(best, time.perf_counter() - t0)
    return best


def projected_tpu_time(num_edges: int, kappa: int, bits: int, iters: int) -> float:
    """Roofline byte model: edge stream (x,y 32b + val `bits`) + P traffic,
    819 GB/s HBM."""
    bytes_per_edge = 8 + bits / 8.0
    p_bytes = 0  # P resident in VMEM (paper: URAM) for the target sizes
    total = (num_edges * bytes_per_edge + p_bytes) * iters
    return total / 819e9


def run(scale: float = 0.02, requests: int = 8, iters: int = 10) -> List[Dict]:
    suite = paper_graph_suite(scale=scale)
    rng = np.random.default_rng(0)
    rows = []
    for name, g in suite.items():
        pers = rng.integers(0, g.num_vertices, requests)
        cfg = PPRConfig(iterations=iters, kappa=requests)
        # CPU float64 oracle (PGX stand-in)
        t_cpu = _time(lambda: ppr_reference(g, pers, iterations=iters))
        # our float32 architecture (F32 column)
        run_ppr(g, pers, cfg)  # warm up jit
        t_f32 = _time(lambda: run_ppr(g, pers, cfg))
        row = {"graph": name, "V": g.num_vertices, "E": g.num_edges,
               "cpu_f64_s": t_cpu, "f32_s": t_f32}
        for bits in BITS:
            fmt = format_for_bits(bits)
            run_ppr(g, pers, cfg, fmt=fmt)  # warm up
            t = _time(lambda: run_ppr(g, pers, cfg, fmt=fmt))
            row[f"q{bits}_s"] = t
            row[f"q{bits}_speedup_vs_cpu"] = t_cpu / t
            row[f"q{bits}_tpu_projected_s"] = projected_tpu_time(
                g.num_edges, requests, bits, iters)
        rows.append(row)
    return rows


def main(scale=0.02):
    rows = run(scale=scale)
    print("# Fig3: name,us_per_call,derived")
    for r in rows:
        for bits in BITS:
            print(f"ppr_fig3_{r['graph']}_q{bits},{r[f'q{bits}_s']*1e6:.0f},"
                  f"speedup_vs_cpu={r[f'q{bits}_speedup_vs_cpu']:.2f}"
                  f";tpu_projected_us={r[f'q{bits}_tpu_projected_s']*1e6:.1f}")
        print(f"ppr_fig3_{r['graph']}_f32,{r['f32_s']*1e6:.0f},"
              f"speedup_vs_cpu={r['cpu_f64_s']/r['f32_s']:.2f}")
    return rows


if __name__ == "__main__":
    main()
