"""Dynamic-graph-updates benchmark: delta-apply latency, warm vs cold
iterations-to-exit, and scoped vs whole-graph invalidation.

Three measurement families, one row each per configuration:

- ``apply_<n>``  host-merge + incremental device-refresh latency of an
  n-edge delta against a live registered graph (pre-quantized at Q1.25, so
  the incremental requantization path is part of the measurement).
- ``warm_vs_cold``  iterations-to-exit under the convergence monitor for the
  same post-delta query set, served by a warm-started service (seeded from
  pre-delta converged columns) and a cold one — the paper's Fig. 7 early-exit
  win compounded by delta ingestion.
- ``scoped_invalidation``  cache entries dropped by a localized delta's
  scoped invalidation vs the whole-graph flush re-registration would cost;
  the row asserts the scoped drop is strictly smaller.

    PYTHONPATH=src python benchmarks/bench_graph_updates.py [--scale 0.02] [--dry-run]

``--dry-run`` is the CI smoke path (tiny graph, one delta size).  Output is
the house ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from repro.graph_updates import localized_delta, random_delta
from repro.graphs import holme_kim_powerlaw
from repro.ppr_serving import PPRQuery, PPRService

DELTA_SIZES = (16, 128, 1024)


def _bench_apply(g, delta_sizes, reps: int, seed: int) -> List[Dict]:
    rows: List[Dict] = []
    for n_edges in delta_sizes:
        svc = PPRService(kappa=8, iterations=5)
        svc.register_graph("g", g, formats=[26])
        rng = np.random.default_rng(seed)
        # warm the merge path once (first call pays numpy internals)
        svc.apply_delta("g", random_delta(g, rng, n_add=4, n_remove=2))
        times = []
        for _ in range(reps):
            rg = svc.registered_graph("g")
            d = random_delta(rg.source, rng, n_add=n_edges,
                             n_remove=max(1, n_edges // 2))
            t0 = time.perf_counter()
            svc.apply_delta("g", d)
            times.append(time.perf_counter() - t0)
        rows.append({
            "case": f"apply_{n_edges}",
            "V": g.num_vertices,
            "E": g.num_edges,
            "delta_edges": n_edges + max(1, n_edges // 2),
            "apply_ms_mean": float(np.mean(times) * 1e3),
            "apply_ms_min": float(np.min(times) * 1e3),
        })
    return rows


def _iters_run(svc, t_before: Dict, t_after: Dict) -> float:
    """Mean iterations actually run per wave between two telemetry snapshots
    (budget · waves − early-exit savings)."""
    waves = t_after["waves"] - t_before["waves"]
    if not waves:
        return 0.0
    saved = t_after["iterations_saved"] - t_before["iterations_saved"]
    return (waves * svc.iterations - saved) / waves


def _bench_warm_vs_cold(g, n_queries: int, iterations: int, seed: int) -> List[Dict]:
    rng = np.random.default_rng(seed)
    verts = rng.integers(0, g.num_vertices, n_queries)
    services = {}
    for label, warm in (("warm", True), ("cold", False)):
        svc = PPRService(kappa=8, iterations=iterations, early_exit=True,
                         warm_start=warm, cache_capacity=0)
        svc.register_graph("g", g, formats=[26])
        services[label] = svc
        svc.run_batch([PPRQuery("g", int(v), k=10, precision=26)
                       for v in verts])
    delta = random_delta(g, np.random.default_rng(seed + 1),
                         n_add=8, n_remove=4)
    iters = {}
    for label, svc in services.items():
        svc.apply_delta("g", delta)
        before = svc.telemetry_summary()
        svc.run_batch([PPRQuery("g", int(v), k=10, precision=26)
                       for v in verts])
        iters[label] = _iters_run(svc, before, svc.telemetry_summary())
    warm_t = services["warm"].telemetry_summary()
    return [{
        "case": "warm_vs_cold",
        "V": g.num_vertices,
        "queries": n_queries,
        "budget": iterations,
        "cold_iters_per_wave": iters["cold"],
        "warm_iters_per_wave": iters["warm"],
        "warm_start_waves": warm_t["warm_start_waves"],
        "warm_start_iterations_saved": warm_t["warm_start_iterations_saved"],
    }]


def _bench_scoped_invalidation(g, n_queries: int, seed: int) -> List[Dict]:
    rng = np.random.default_rng(seed)
    verts = rng.choice(g.num_vertices, size=min(n_queries, g.num_vertices),
                       replace=False)
    svc = PPRService(kappa=8, iterations=5)
    svc.register_graph("g", g, formats=[26])
    svc.run_batch([PPRQuery("g", int(v), k=10, precision=26) for v in verts])
    cached = svc.telemetry_summary()["lru_size"]
    # low-connectivity endpoints keep the 1-hop frontier small (touching a
    # hub would put its whole in-neighborhood in the frontier)
    delta = localized_delta(g, rng, n_add=2, n_remove=1)
    report = svc.apply_delta("g", delta)
    dropped, retained = report["cache_dropped"], report["cache_retained"]
    assert dropped < cached, (
        f"scoped invalidation dropped every cached entry ({dropped}/{cached}) "
        f"on a localized delta — scoping is broken")
    return [{
        "case": "scoped_invalidation",
        "V": g.num_vertices,
        "cached_before": int(cached),
        "frontier_size": report["frontier_size"],
        "scoped_dropped": int(dropped),
        "scoped_retained": int(retained),
        "whole_graph_would_drop": int(cached),
    }]


def run(scale: float = 0.02, n_queries: int = 48, iterations: int = 80,
        delta_sizes=DELTA_SIZES, reps: int = 5, seed: int = 0) -> List[Dict]:
    g = holme_kim_powerlaw(max(256, int(128000 * scale)), m=3, seed=1)
    rows = _bench_apply(g, delta_sizes, reps, seed)
    rows += _bench_warm_vs_cold(g, n_queries, iterations, seed)
    rows += _bench_scoped_invalidation(g, n_queries, seed)
    return rows


def main(scale: float = 0.02, dry_run: bool = False) -> List[Dict]:
    if dry_run:
        rows = run(scale=0.005, n_queries=8, iterations=80,
                   delta_sizes=(16,), reps=2)
    else:
        rows = run(scale=scale)
    print("# graph_updates: name,us_per_call,derived")
    for r in rows:
        if r["case"].startswith("apply_"):
            print(f"{r['case']},{r['apply_ms_mean']*1e3:.0f},"
                  f"edges={r['delta_edges']};min_ms={r['apply_ms_min']:.2f};"
                  f"V={r['V']}")
        elif r["case"] == "warm_vs_cold":
            print(f"warm_vs_cold,0,"
                  f"cold_iters={r['cold_iters_per_wave']:.2f};"
                  f"warm_iters={r['warm_iters_per_wave']:.2f};"
                  f"saved={r['warm_start_iterations_saved']}")
        else:
            print(f"scoped_invalidation,0,"
                  f"dropped={r['scoped_dropped']};retained={r['scoped_retained']};"
                  f"whole_graph={r['whole_graph_would_drop']};"
                  f"frontier={r['frontier_size']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny graph, one delta size — the CI smoke path")
    args = ap.parse_args()
    main(scale=args.scale, dry_run=args.dry_run)
