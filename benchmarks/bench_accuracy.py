"""Fig. 4/5/6 analogue: ranking accuracy vs fixed-point bit-width.

Fig. 4: per-graph errors@N / edit@N / NDCG for the 2e6-edge graphs.
Fig. 5: aggregated MAE / precision@N / Kendall τ over all graphs.
Fig. 6: sparsity × bit-width sweep (precision@50).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import PPRConfig, format_for_bits, run_ppr
from repro.core.metrics import aggregate_reports, full_report
from repro.graphs import erdos_renyi, paper_graph_suite, ppr_reference

BITS = [14, 16, 18, 20, 22, 24, 26]


def _score(g, bits, requests=4, iters=10):
    rng = np.random.default_rng(1)
    pers = rng.integers(0, g.num_vertices, requests)
    ref = ppr_reference(g, pers, iterations=100)
    got, _ = run_ppr(g, pers, PPRConfig(iterations=iters),
                     fmt=format_for_bits(bits) if bits else None)
    return aggregate_reports([full_report(got[:, i], ref[:, i])
                              for i in range(requests)])


def run(scale: float = 0.02) -> List[Dict]:
    suite = paper_graph_suite(scale=scale)
    rows = []
    for name in ["gnp_2e5", "ws_2e5", "pl_2e5"]:          # Fig 4 graphs
        for bits in BITS:
            rep = _score(suite[name], bits)
            rows.append(dict(rep, graph=name, bits=bits, fig="fig4"))
    # Fig 5: aggregate over the full suite at each bit width
    for bits in BITS:
        reps = [_score(g, bits, requests=2) for g in suite.values()]
        agg = aggregate_reports(reps)
        rows.append(dict(agg, graph="all", bits=bits, fig="fig5"))
    # Fig 6: sparsity sweep at fixed |V|
    v = max(64, int(1e5 * scale))
    for avg_deg in [2, 10, 50]:
        g = erdos_renyi(v, v * avg_deg, seed=42)
        for bits in [16, 20, 26]:
            rep = _score(g, bits, requests=2)
            rows.append(dict(rep, graph=f"gnp_deg{avg_deg}", bits=bits, fig="fig6"))
    return rows


def main(scale=0.02):
    rows = run(scale=scale)
    print("# Fig4/5/6: name,us_per_call,derived")
    for r in rows:
        print(f"ppr_{r['fig']}_{r['graph']}_b{r['bits']},0,"
              f"ndcg={r['ndcg']:.5f};edit10={r['edit@10']:.2f};"
              f"errors10={r['errors@10']:.2f};prec50={r['precision@50']:.3f};"
              f"kendall50={r['kendall@50']:.4f};mae={r['mae']:.2e}")
    return rows


if __name__ == "__main__":
    main()
