"""Fig. 7 analogue: convergence speed, fixed-point vs floating-point.

Measures ‖P_{t+1} − P_t‖₂ per iteration; reports the first iteration where the
error drops below 1e-6 (the paper's threshold) and where fixed point reaches
its absorbing state (delta == 0) — the mechanism behind the paper's "2x faster
convergence ⇒ additional 2x speedup" claim.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import PPRConfig, format_for_bits, run_ppr
from repro.graphs import paper_graph_suite

BITS = [20, 26]
THRESH = 1e-6


def iterations_to(deltas: np.ndarray, thresh: float) -> int:
    hit = np.nonzero(deltas < thresh)[0]
    return int(hit[0]) + 1 if hit.size else len(deltas) + 1


def run(scale: float = 0.02, iters: int = 40) -> List[Dict]:
    """Multi-threshold view: fixed point's delta sits at its quantization noise
    floor (≈ √V·2^-f) until it snaps to exactly 0 at the absorbing state —
    the mechanism behind the paper's Fig. 7 (their lines truncate at 1e-7,
    which float reaches asymptotically but fixed point reaches *exactly*)."""
    suite = paper_graph_suite(scale=scale)
    rng = np.random.default_rng(2)
    rows = []
    for name in ["gnp_1e5", "ws_1e5", "pl_1e5", "amazon_like"]:
        g = suite[name]
        pers = rng.integers(0, g.num_vertices, 4)
        cfg = PPRConfig(iterations=iters)
        _, d_float = run_ppr(g, pers, cfg)
        d_float = np.asarray(d_float)
        row = {"graph": name,
               "float_iters": iterations_to(d_float, THRESH),
               "float_iters_1e7": iterations_to(d_float, 1e-7),
               "float_exact": iterations_to(d_float, 0.0) if (d_float == 0).any()
               else -1}
        for bits in BITS:
            _, d = run_ppr(g, pers, cfg, fmt=format_for_bits(bits))
            d = np.asarray(d)
            row[f"q{bits}_iters"] = iterations_to(d, THRESH)
            zero_hit = np.nonzero(d == 0.0)[0]
            row[f"q{bits}_absorbing"] = int(zero_hit[0]) + 1 if zero_hit.size else -1
        row["speedup_q26_vs_float"] = row["float_iters"] / max(1, row["q26_iters"])
        rows.append(row)
    return rows


def main(scale=0.02):
    rows = run(scale=scale)
    print("# Fig7: name,us_per_call,derived")
    for r in rows:
        print(f"ppr_fig7_{r['graph']},0,"
              f"float_iters={r['float_iters']};float_1e7={r['float_iters_1e7']};"
              f"float_exact={r['float_exact']};q26_iters={r['q26_iters']};"
              f"q20_iters={r['q20_iters']};absorbing_q26={r['q26_absorbing']};"
              f"absorbing_q20={r['q20_absorbing']};"
              f"convergence_speedup={r['speedup_q26_vs_float']:.2f}")
    return rows


if __name__ == "__main__":
    main()
